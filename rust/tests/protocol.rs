//! Wire-protocol robustness: seeded, structure-aware fuzzing of the
//! JSON-lines protocol.
//!
//! Two layers:
//! * 10k mutated request lines through [`parse_wire_request`] — the
//!   parser must never panic (truncations, type swaps, random bytes,
//!   pathological nesting, huge numbers) and must classify every line
//!   as `Ok` or `Err`;
//! * a smaller corpus against a LIVE server ([`serve_listener`] on an
//!   ephemeral port, sim engine behind it) — every non-empty line must
//!   be answered, terminating in a `done`, an `error` envelope, or a
//!   command response; the connection and the engine survive the whole
//!   corpus.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, CancelRegistry, Engine};
use rsd::coordinator::server::{parse_wire_request, serve_listener, ServeCtx};
use rsd::coordinator::Metrics;
use rsd::sim::SimLm;
use rsd::tokenizer::Tokenizer;
use rsd::trace::Tracer;
use rsd::util::json::Json;
use rsd::util::Rng;

/// Valid protocol lines used as mutation bases (and, unmutated, as the
/// "parser still accepts good input" control group).
const TEMPLATES: &[&str] = &[
    r#"{"prompt": "hello world", "max_tokens": 4}"#,
    r#"{"prompt": "the quick brown fox", "max_tokens": 3, "decoder": "rsd-s:2x2", "temperature": 0.7, "top_p": 0.9}"#,
    r#"{"prompt": "a", "max_tokens": 2, "id": 7, "priority": 2, "deadline_ms": 1000, "stream": true}"#,
    r#"{"prompt": "stop here", "max_tokens": 3, "stop": [1, 2]}"#,
    r#"{"cmd": "metrics"}"#,
    r#"{"cmd": "cancel", "id": 3}"#,
];

const FIELDS: &[&str] = &[
    "prompt",
    "max_tokens",
    "decoder",
    "temperature",
    "top_p",
    "stop",
    "priority",
    "deadline_ms",
    "stream",
    "id",
    "cmd",
];

/// Typed/extreme values for structure-aware field swaps: right types,
/// wrong types, boundary numbers, nested junk.
const VALUES: &[&str] = &[
    r#""hello world""#,
    r#""""#,
    "0",
    "-1",
    "1e308",
    "-1e308",
    "18446744073709551616",
    "null",
    "true",
    "false",
    "[1, 2, 3]",
    r#"{"a": [{}]}"#,
    r#""rsd-s:3x3""#,
    r#""bogus:decoder""#,
    "-0.5",
    "3.5",
    r#""metrics""#,
    r#""cancel""#,
    "[[[[[]]]]]",
];

/// One seeded fuzz line: a structured random object, a byte-mutated
/// template, or raw garbage.
fn fuzz_line(rng: &mut Rng) -> String {
    match rng.gen_range(8) {
        // random object from known fields x typed/extreme values
        0..=2 => {
            let n = rng.gen_range(6);
            let fields: Vec<String> = (0..n)
                .map(|_| {
                    format!(
                        r#""{}": {}"#,
                        FIELDS[rng.gen_range(FIELDS.len())],
                        VALUES[rng.gen_range(VALUES.len())]
                    )
                })
                .collect();
            format!("{{{}}}", fields.join(", "))
        }
        // byte-level mutation of a valid template
        3..=5 => {
            let base = TEMPLATES[rng.gen_range(TEMPLATES.len())];
            let mut bytes = base.as_bytes().to_vec();
            match rng.gen_range(3) {
                0 => bytes.truncate(rng.gen_range(bytes.len().max(1))),
                1 => {
                    let i = rng.gen_range(bytes.len());
                    bytes[i] = (rng.next_u64() & 0xff) as u8;
                }
                _ => {
                    let at = rng.gen_range(bytes.len() + 1);
                    let ins: Vec<u8> =
                        (0..1 + rng.gen_range(8)).map(|_| (rng.next_u64() & 0xff) as u8).collect();
                    bytes.splice(at..at, ins);
                }
            }
            String::from_utf8_lossy(&bytes).into_owned()
        }
        // deep nesting (must hit the parser's depth guard, not the stack)
        6 => {
            let depth = 1 + rng.gen_range(512);
            format!("{}1{}", "[".repeat(depth), "]".repeat(depth))
        }
        // raw garbage
        _ => {
            let n = rng.gen_range(64);
            (0..n).map(|_| (rng.next_u64() & 0xff) as u8).map(|b| b as char).collect()
        }
    }
}

/// 10k seeded mutations through the line parser: no panics, every line
/// classified, and the control group (unmutated templates) still parses.
#[test]
fn parser_survives_10k_structure_aware_mutations() {
    let tok = Tokenizer::new();
    let mut rng = Rng::seed_from_u64(0xF0CC);
    let (mut oks, mut errs) = (0usize, 0usize);
    for i in 0..10_000 {
        let line = if i % 100 == 0 {
            TEMPLATES[i / 100 % TEMPLATES.len()].to_string()
        } else {
            fuzz_line(&mut rng)
        };
        match parse_wire_request(&line, &tok) {
            Ok(_) => oks += 1,
            Err(_) => errs += 1,
        }
    }
    assert!(oks > 0, "corpus never produced a valid request");
    assert!(errs > 0, "corpus never produced an invalid request");
}

/// Handcrafted adversarial inputs: pathological nesting and size must
/// come back as clean `Err`s (depth guard, not a stack overflow or
/// panic), while boundary numerics stay accepted-and-clamped.
#[test]
fn parser_rejects_pathological_inputs_without_panicking() {
    let tok = Tokenizer::new();
    let deep_arr = format!("{}1{}", "[".repeat(10_000), "]".repeat(10_000));
    assert!(parse_wire_request(&deep_arr, &tok).is_err());
    let deep_obj = format!(r#"{{"prompt": {}"x"{}}}"#, r#"{"a": "#.repeat(8_192), "}".repeat(8_192));
    assert!(parse_wire_request(&deep_obj, &tok).is_err());
    let huge = format!(r#"{{"prompt": "{}"}}"#, "a".repeat(1 << 20));
    assert!(parse_wire_request(&huge, &tok).is_ok(), "long valid prompt must parse");
    assert!(parse_wire_request("", &tok).is_err());
    assert!(parse_wire_request("\u{0}\u{1}\u{2}", &tok).is_err());
    // huge max_tokens clamps instead of overflowing
    let w = parse_wire_request(r#"{"prompt": "a", "max_tokens": 1e308}"#, &tok).unwrap();
    assert!(w.max_new <= 192);
    // id 0 and non-numeric ids are rejected, not mapped
    assert!(parse_wire_request(r#"{"prompt": "a", "id": 0}"#, &tok).is_err());
    assert!(parse_wire_request(r#"{"prompt": "a", "id": "seven"}"#, &tok).is_err());
}

/// Live-server fuzz: every non-empty line is answered with a terminal
/// reply (`done` / `error` envelope / command response); tokens stream
/// in between; the connection survives the whole corpus; crafted
/// requests round-trip their client id and the cancel command acks.
#[test]
fn live_server_answers_every_line_with_a_terminal_reply() {
    let (target, draft) = SimLm::pair(0, 0.8, 64);
    let cfg = EngineConfig {
        max_concurrency: 4,
        max_queue: 64,
        default_max_tokens: 8,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.6, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 1,
        fused: true,
        ..EngineConfig::default()
    };
    let metrics = Arc::new(Metrics::default());
    let cancels = CancelRegistry::default();
    let engine = Engine::with_telemetry(target, draft, cfg, metrics.clone(), Tracer::new(0))
        .with_cancels(cancels.clone());
    let (tx, _engine_handle) = spawn(engine);

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().unwrap();
    let ctx = ServeCtx {
        metrics: Some(metrics),
        trace: Tracer::new(0),
        cancels: Some(cancels),
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = serve_listener(listener, tx, ctx);
    });

    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut wr = stream.try_clone().unwrap();
    let mut rd = BufReader::new(stream);

    // One request line -> reply lines until the first non-token line,
    // which is the terminal reply for that request.
    let read_terminal = |rd: &mut BufReader<TcpStream>, sent: &str| -> Json {
        loop {
            let mut line = String::new();
            let n = rd.read_line(&mut line).unwrap_or_else(|e| {
                panic!("no terminal reply for line {sent:?}: {e}");
            });
            assert!(n > 0, "server closed the connection on line {sent:?}");
            let j = Json::parse(&line)
                .unwrap_or_else(|e| panic!("unparseable reply {line:?} to {sent:?}: {e}"));
            if j.get("tokens").is_none() && j.get("token").is_none() {
                return j;
            }
        }
    };

    let mut rng = Rng::seed_from_u64(0xBEEF);
    let mut answered = 0usize;
    for i in 0..400 {
        let raw = if i % 40 == 0 {
            TEMPLATES[i / 40 % TEMPLATES.len()].to_string()
        } else {
            fuzz_line(&mut rng)
        };
        // one send == one protocol line: strip embedded line breaks and
        // skip lines the server ignores (blank after trim)
        let line: String =
            raw.chars().map(|c| if c == '\n' || c == '\r' { ' ' } else { c }).collect();
        if line.trim().is_empty() {
            continue;
        }
        writeln!(wr, "{line}").expect("send fuzz line");
        let reply = read_terminal(&mut rd, &line);
        assert!(
            reply.get("done").is_some()
                || reply.get("error").is_some()
                || reply.get("metrics").is_some()
                || reply.get("trace").is_some()
                || reply.get("cancelled").is_some(),
            "reply to {line:?} is not a terminal: {reply:?}"
        );
        answered += 1;
    }
    assert!(answered >= 300, "corpus degenerated to blank lines");

    // Crafted end-to-end checks on the same connection: client id
    // round-trips into the done envelope ...
    writeln!(wr, r#"{{"prompt": "hi there", "max_tokens": 2, "id": 9}}"#).unwrap();
    let done = read_terminal(&mut rd, "id round-trip");
    let id = done
        .get("done")
        .and_then(|d| d.get("id"))
        .and_then(Json::as_usize)
        .expect("done envelope carries the id");
    assert_eq!(id, 9);
    // ... the cancel command acks with the unmasked id ...
    writeln!(wr, r#"{{"cmd": "cancel", "id": 9}}"#).unwrap();
    let ack = read_terminal(&mut rd, "cancel ack");
    assert_eq!(ack.get("cancelled").and_then(Json::as_usize), Some(9));
    // ... and a structured error envelope carries {code, retryable}.
    writeln!(wr, r#"{{"prompt": 42}}"#).unwrap();
    let err = read_terminal(&mut rd, "typed error envelope");
    let env = err.get("error").expect("error envelope");
    assert!(env.get("code").and_then(Json::as_str).is_some(), "{err:?}");
    assert!(env.get("retryable").is_some(), "{err:?}");
}
