//! Speculation-analytics reconciliation suite: the acceptance ledger
//! is accounting, not sampling — its totals must agree EXACTLY with
//! the sum of per-request [`DecodeStats`], family by family, across a
//! mixed ar/rsd-c/rsd-s/adaptive workload pushed through the serving
//! engine with an undersized paged KV pool (preemption churn) and a
//! seeded transient-fault schedule (abort + retry churn).
//!
//! Why exactness is the right bar: steppers bump their `DecodeStats`
//! and the ledger at the same commit boundary, aborted rounds reach
//! neither, and retried rounds replay from a round-start RNG snapshot
//! — so any drift between the two is a double- or under-count bug,
//! never legitimate noise.

use std::collections::BTreeSet;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use rsd::chaos::{ChaosLm, FaultPlan};
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig, SamplingPatch};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::coordinator::metrics::{Metrics, Snapshot};
use rsd::decode::DecodeStats;
use rsd::kvcache::KvConfig;
use rsd::obs::{Analytics, Family, LedgerTotals, MAX_LEVELS};
use rsd::sim::SimLm;
use rsd::trace::Tracer;
use rsd::util::json::Json;
use rsd::util::Rng;

const VOCAB: usize = 32;
const N_REQUESTS: u64 = 120;
const SIM_SEED: u64 = 17;
const ENGINE_SEED: u64 = 99;

#[derive(Clone)]
struct Spec {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    decoder: Option<DecoderConfig>,
    sampling: Option<SamplingPatch>,
    priority: u8,
}

/// Seeded-random workload over EVERY stepper kind, adaptive included:
/// reconciliation (unlike the soak's bit-identity) does not care that
/// adaptive tree shapes depend on scheduling, so nothing is excluded.
fn build_workload(seed: u64) -> Vec<Spec> {
    let mut rng = Rng::seed_from_u64(seed);
    let decoders: [Option<DecoderConfig>; 8] = [
        None, // engine default (rsd-s:3x3)
        Some(DecoderConfig::Ar),
        Some(DecoderConfig::Sd { l: 3 }),
        Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
        Some(DecoderConfig::RsdS { w: 3, l: 2 }),
        Some(DecoderConfig::SpecTr { k: 2, l: 2 }),
        Some(DecoderConfig::Adaptive {
            budget: 9,
            family: rsd::config::AdaptiveFamily::Auto,
        }),
        Some(DecoderConfig::Adaptive {
            budget: 6,
            family: rsd::config::AdaptiveFamily::RsdS,
        }),
    ];
    (0..N_REQUESTS)
        .map(|id| {
            let prompt_len = 1 + rng.gen_range(20);
            let prompt: Vec<u32> =
                (0..prompt_len).map(|_| rng.gen_range(VOCAB) as u32).collect();
            let max_new = 1 + rng.gen_range(12);
            let decoder = decoders[rng.gen_range(decoders.len())].clone();
            let sampling = if rng.gen_range(4) == 0 {
                Some(SamplingPatch {
                    stop: Some(vec![rng.gen_range(VOCAB) as u32]),
                    ..Default::default()
                })
            } else {
                None
            };
            Spec { id, prompt, max_new, decoder, sampling, priority: rng.gen_range(3) as u8 }
        })
        .collect()
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        max_concurrency: 6,
        max_queue: 256,
        default_max_tokens: 8,
        sampling: SamplingConfig::new(0.6, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: ENGINE_SEED,
        fused: true,
        stats_window_rounds: 8,
        stats_windows: 4, // deliberately tiny: the run wraps the ring many times
        ..EngineConfig::default()
    }
}

/// Drive the workload to completion and return per-request stats (in
/// submission order), the analytics handle and the metrics snapshot.
fn run(
    specs: &[Spec],
    cfg: EngineConfig,
    plan: FaultPlan,
) -> (Vec<DecodeStats>, Analytics, Snapshot) {
    let kv = KvConfig { num_blocks: 24, block_size: 8, share: true };
    let (t, d) = SimLm::pair_paged(SIM_SEED, 0.8, VOCAB, kv);
    let chaos = ChaosLm::new(t, plan);
    let engine = Engine::with_telemetry(
        chaos,
        d,
        cfg,
        Arc::new(Metrics::default()),
        Tracer::off(),
    );
    let analytics = engine.analytics.clone();
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for s in specs {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: s.id,
            prompt: s.prompt.clone(),
            max_new: s.max_new,
            decoder: s.decoder.clone(),
            sampling: s.sampling.clone(),
            priority: s.priority,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push((s.id, rrx));
    }
    drop(tx);
    let mut stats = Vec::new();
    for (id, rrx) in receivers {
        loop {
            match rrx.recv_timeout(Duration::from_secs(180)) {
                Ok(Event::Tokens(_)) => {}
                Ok(Event::Done(r)) => {
                    stats.push(r.stats);
                    break;
                }
                Ok(Event::Error(e)) => panic!("request {id} failed: {e}"),
                Err(e) => panic!("request {id} starved or engine deadlocked: {e}"),
            }
        }
    }
    (stats, analytics, handle.join().unwrap().snapshot())
}

/// Sum a workload's per-request stats into per-family expectations.
fn expected_by_family(
    specs: &[Spec],
    stats: &[DecodeStats],
    default: &DecoderConfig,
) -> Vec<(Family, LedgerTotals)> {
    let mut by_family: Vec<(Family, LedgerTotals)> = Vec::new();
    for (spec, st) in specs.iter().zip(stats) {
        let fam = Family::of(spec.decoder.as_ref().unwrap_or(default));
        let idx = match by_family.iter().position(|(f, _)| *f == fam) {
            Some(i) => i,
            None => {
                by_family.push((fam, LedgerTotals::default()));
                by_family.len() - 1
            }
        };
        let slot = &mut by_family[idx].1;
        slot.target_forwards += st.decode_calls as u64;
        slot.tree_nodes += st.tree_nodes as u64;
        slot.accepted += st.accepted_draft_tokens as u64;
        slot.bonus += st.bonus_tokens as u64;
        slot.committed += st.generated as u64;
        if slot.level_attempts.len() < MAX_LEVELS {
            slot.level_attempts.resize(MAX_LEVELS, 0);
            slot.level_accepts.resize(MAX_LEVELS, 0);
        }
        for (lvl, (&a, &s)) in st.level_attempts.iter().zip(&st.level_accepts).enumerate() {
            let lvl = lvl.min(MAX_LEVELS - 1);
            slot.level_attempts[lvl] += a;
            slot.level_accepts[lvl] += s;
        }
    }
    by_family
}

/// The reconciliation property (see module docs): every ledger row ==
/// the sum of the DecodeStats of the requests routed to that family,
/// exactly, under preemption + transient-fault retry churn.
#[test]
fn ledger_reconciles_exactly_with_per_request_stats() {
    let specs = build_workload(7177);
    // transient faults on a handful of target sessions: each trips an
    // abort + requeue + replay; persistent faults are deliberately
    // absent so every request completes and reports its DecodeStats
    let plan = FaultPlan {
        transient_sessions: [2u64, 9, 23, 41].into_iter().collect::<BTreeSet<u64>>(),
        ..FaultPlan::none()
    };
    let (stats, analytics, snap) = run(&specs, base_cfg(), plan);

    // the run exercised what it claims to: churn actually happened
    assert_eq!(snap.completed, N_REQUESTS);
    assert_eq!(snap.failed, 0);
    assert!(snap.preemptions > 0, "undersized pool never preempted");
    assert!(snap.retries > 0, "transient faults never tripped a retry");

    let expected = expected_by_family(&specs, &stats, &base_cfg().decoder);
    let mut families_seen = 0u32;
    for (fam, want) in &expected {
        let got = analytics.family_totals(*fam);
        families_seen += 1;
        assert_eq!(
            got.target_forwards,
            want.target_forwards,
            "{}: target_forwards ledger vs stats",
            fam.name()
        );
        assert_eq!(got.tree_nodes, want.tree_nodes, "{}: tree_nodes", fam.name());
        assert_eq!(got.accepted, want.accepted, "{}: accepted", fam.name());
        assert_eq!(got.committed, want.committed, "{}: committed", fam.name());
        if *fam == Family::Ar {
            // AR accounting: no draft tree, every committed token is a
            // "bonus" (target-sampled) token, nothing is ever resampled
            assert_eq!(got.tree_nodes, 0, "ar: tree_nodes must be 0");
            assert_eq!(got.accepted, 0, "ar: accepted must be 0");
            assert_eq!(got.bonus, got.committed, "ar: bonus == committed");
            assert_eq!(got.resamples, 0, "ar: resamples must be 0");
        } else {
            assert_eq!(got.bonus, want.bonus, "{}: bonus", fam.name());
            assert_eq!(
                got.level_attempts,
                want.level_attempts,
                "{}: per-level attempts",
                fam.name()
            );
            assert_eq!(
                got.level_accepts,
                want.level_accepts,
                "{}: per-level accepts",
                fam.name()
            );
            // committed = accepted + bonus + residual resamples, so the
            // resample count is pinned by the other three
            assert_eq!(
                got.resamples,
                got.committed - got.accepted - got.bonus,
                "{}: resamples identity",
                fam.name()
            );
        }
    }
    assert!(families_seen >= 4, "workload was expected to span >= 4 families");

    // the grand total is the sum of the family rows — and matches the
    // engine's own token counter
    let totals = analytics.totals();
    let committed_sum: u64 = expected.iter().map(|(_, t)| t.committed).sum();
    assert_eq!(totals.committed, committed_sum);
    assert_eq!(totals.committed, snap.tokens_out, "ledger vs Metrics::tokens_out");
    let forwards_sum: u64 = expected.iter().map(|(_, t)| t.target_forwards).sum();
    assert_eq!(totals.target_forwards, forwards_sum);
}

/// The windowed report stays coherent after heavy ring wraparound: the
/// tiny 4-window ring rotates dozens of times during the run, yet any
/// requested span must clamp to retained history — never a negative
/// delta, never an aggregate exceeding the cumulative ledger.
#[test]
fn windowed_report_survives_ring_wraparound() {
    let specs = build_workload(90210);
    let (_, analytics, snap) = run(&specs, base_cfg(), FaultPlan::none());
    assert_eq!(snap.completed, N_REQUESTS);

    let totals = analytics.totals();
    for window in [1usize, 3, 4, 50, 10_000] {
        let j = analytics.stats_json(window);
        let w = j.get("window").expect("window object");
        let committed = w.get("committed").and_then(Json::as_usize).unwrap() as u64;
        let forwards = w.get("target_forwards").and_then(Json::as_usize).unwrap() as u64;
        assert!(
            committed <= totals.committed,
            "window {window}: aggregate committed {committed} exceeds lifetime {}",
            totals.committed
        );
        assert!(forwards <= totals.target_forwards, "window {window}: forwards");
        let trend = match j.get("trend") {
            Some(Json::Arr(t)) => t.len(),
            other => panic!("trend must be an array, got {other:?}"),
        };
        // a 4-slot ring retains at most 3 complete trend windows (both
        // boundaries must survive) plus nothing fabricated beyond the
        // request
        assert!(trend <= window.min(4), "window {window}: trend len {trend}");
        // the report round-trips through the wire format
        let parsed = Json::parse(&j.to_string()).expect("stats JSON re-parses");
        assert!(parsed.get("cumulative").is_some());
    }

    // an empty window request against a fresh (ticked-but-idle) handle
    // yields zeroes, not NaNs — mirrors the unit test, but through the
    // full serve-side JSON path
    let idle = Analytics::new(4, 4, 0, 0);
    idle.tick(&Metrics::default(), 0, 0);
    let j = idle.stats_json(1);
    let w = j.get("window").expect("window object");
    assert_eq!(w.get("committed").and_then(Json::as_usize), Some(0));
    let tps = match w.get("tokens_per_sec") {
        Some(Json::Num(n)) => *n,
        other => panic!("tokens_per_sec must be a number, got {other:?}"),
    };
    assert!(tps.is_finite(), "idle window must not produce NaN/inf rates");
}
