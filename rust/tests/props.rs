//! Property-based tests (in-repo harness: seeded random case sweeps, the
//! offline substitute for proptest): the paper's two theorems plus the
//! coordinator-state invariants.

use rsd::decode::rrs::{LevelOutcome, Rrs, VerifyRule};
use rsd::llm::EvalNode;
use rsd::sampling::{gumbel_top_k, process_logits, sample_categorical, tv_distance, LogProbs};
use rsd::tree::SessionCore;
use rsd::util::{Json, Rng};

fn random_dist(rng: &mut Rng, n: usize, sharp: f64) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| (-(rng.gen_f64_open()).ln()).powf(sharp)).collect();
    let z: f64 = v.iter().sum();
    for x in &mut v {
        *x /= z;
    }
    v
}

fn lp(probs: &[f64]) -> LogProbs {
    LogProbs(probs.iter().map(|&p| if p > 0.0 { p.ln() } else { f64::NEG_INFINITY }).collect())
}

/// Theorem 3.1, swept over random (p, q, K): siblings drawn without
/// replacement via Gumbel-Top-k + RRS must recover q exactly.
#[test]
fn prop_rrs_recovers_target_over_random_instances() {
    let mut meta = Rng::seed_from_u64(0xabc);
    for case in 0..12 {
        let n = 3 + meta.gen_range(6); // vocab 3..8
        let k = 1 + meta.gen_range(n.min(4)); // 1..4 siblings
        let sharp_p = 1.0 + meta.gen_f64() * 2.0;
        let sharp_q = 1.0 + meta.gen_f64() * 2.0;
        let p = random_dist(&mut meta, n, sharp_p);
        let q = random_dist(&mut meta, n, sharp_q);
        let plp = lp(&p);
        let qlp = lp(&q);
        let mut rng = Rng::seed_from_u64(case);
        let trials = 120_000;
        let mut hist = vec![0f64; n];
        for _ in 0..trials {
            let sib: Vec<u32> =
                gumbel_top_k(&plp, k, &mut rng).iter().map(|&(i, _)| i as u32).collect();
            let tok = match Rrs.verify(&sib, &plp, &qlp, &mut rng) {
                LevelOutcome::Accept { pos } => sib[pos],
                LevelOutcome::Reject { token } => token,
            };
            hist[tok as usize] += 1.0;
        }
        for h in &mut hist {
            *h /= trials as f64;
        }
        let tv = tv_distance(&hist, &q);
        assert!(tv < 0.012, "case {case} (n={n}, k={k}): TV {tv}");
    }
}

/// Theorem 3.2: Stochastic Beam Search siblings of a common parent follow
/// sampling without replacement from p(.|parent). We verify the exact
/// K=2 joint: P(first=i, second=j) = p_i p_j / (1 - p_i), where
/// first/second are the top-2 by truncated-Gumbel psi under one parent.
#[test]
fn prop_sbs_siblings_without_replacement() {
    use rsd::sampling::{gumbel, truncated_gumbel};
    let mut meta = Rng::seed_from_u64(0x5b5);
    for case in 0..4 {
        let n = 3 + meta.gen_range(3);
        let p = random_dist(&mut meta, n, 1.5);
        let plp = lp(&p);
        let mut rng = Rng::seed_from_u64(case + 100);
        let trials = 150_000;
        let mut joint = std::collections::HashMap::new();
        for _ in 0..trials {
            // one SBS expansion from a parent with psi_parent = 0
            let phi_tilde: Vec<f64> = plp.0.iter().map(|&l| l + gumbel(&mut rng)).collect();
            let z = phi_tilde.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let psi = truncated_gumbel(0.0, z, &phi_tilde);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| psi[b].partial_cmp(&psi[a]).unwrap());
            *joint.entry((idx[0], idx[1])).or_insert(0usize) += 1;
        }
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let expect = p[i] * p[j] / (1.0 - p[i]);
                let emp = *joint.get(&(i, j)).unwrap_or(&0) as f64 / trials as f64;
                assert!(
                    (emp - expect).abs() < 0.012,
                    "case {case} ({i},{j}): {emp} vs {expect}"
                );
            }
        }
    }
}

/// Coordinator-state invariant: under random add/commit sequences, slots
/// stay unique, capacity accounting is exact, and committed prefixes grow
/// consistently (the zero-copy FilterKVCache can never leak or alias).
#[test]
fn prop_session_core_slot_invariants() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let cache = 24 + rng.gen_range(40);
        let mut s = SessionCore::new(cache);
        let total_slots = cache - 1;
        for _round in 0..30 {
            // random forest of 1..8 nodes
            let n = 1 + rng.gen_range(8);
            if s.capacity_left() < n {
                break;
            }
            let mut nodes = Vec::new();
            for i in 0..n {
                let node = if i == 0 || rng.gen_f64() < 0.3 {
                    EvalNode::root(rng.gen_range(64) as u32)
                } else {
                    EvalNode::child(rng.gen_range(64) as u32, rng.gen_range(i))
                };
                nodes.push(node);
            }
            let before_free = s.capacity_left();
            let range = s.add_pending(&nodes).unwrap();
            assert_eq!(s.capacity_left(), before_free - n);

            // slots unique across prefix + pending
            let mut all: Vec<u32> = s.prefix_slots.clone();
            all.extend(s.pending.iter().map(|p| p.slot));
            let len = all.len();
            all.sort();
            all.dedup();
            assert_eq!(all.len(), len, "seed {seed}: slot aliasing");
            assert!(all.iter().all(|&x| (x as usize) < total_slots));

            // commit a random chain starting from a root node
            let roots: Vec<usize> =
                range.clone().filter(|&i| s.pending[i].parent == -1).collect();
            let mut chain = vec![roots[rng.gen_range(roots.len())]];
            loop {
                let last = *chain.last().unwrap();
                let kids: Vec<usize> = range
                    .clone()
                    .filter(|&i| s.pending[i].parent == last as i64)
                    .collect();
                if kids.is_empty() || rng.gen_f64() < 0.4 {
                    break;
                }
                chain.push(kids[rng.gen_range(kids.len())]);
            }
            let prefix_before = s.prefix_len();
            s.commit(&chain).unwrap();
            assert_eq!(s.prefix_len(), prefix_before + chain.len());
            assert!(s.pending.is_empty());
            // conservation: free + prefix == total
            assert_eq!(s.capacity_left() + s.prefix_len(), total_slots, "seed {seed}");
        }
    }
}

/// JSON round-trip over randomly generated documents.
#[test]
fn prop_json_roundtrip_random_docs() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_f64() < 0.5),
            2 => Json::Num((rng.gen_f64() * 2000.0 - 1000.0 * rng.gen_f64()).round() / 8.0),
            3 => {
                let alphabet: Vec<char> = "ab\"\\\nπé x".chars().collect();
                let n = rng.gen_range(8);
                Json::Str((0..n).map(|_| alphabet[rng.gen_range(alphabet.len())]).collect())
            }
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200 {
        let mut rng = Rng::seed_from_u64(seed);
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, doc, "seed {seed}: {text}");
    }
}

/// Degenerate corners of RRS.
#[test]
fn prop_rrs_corner_cases() {
    let mut rng = Rng::seed_from_u64(9);
    // q concentrated where p is not
    let p = lp(&[0.98, 0.01, 0.01]);
    let q = lp(&[0.0, 0.0, 1.0]);
    for _ in 0..2000 {
        let sib: Vec<u32> =
            gumbel_top_k(&p, 2, &mut rng).iter().map(|&(i, _)| i as u32).collect();
        let tok = match Rrs.verify(&sib, &p, &q, &mut rng) {
            LevelOutcome::Accept { pos } => sib[pos],
            LevelOutcome::Reject { token } => token,
        };
        assert_eq!(tok, 2, "must always emit the only q-supported token");
    }
    // identical p == q: the first sibling is always accepted
    let d = lp(&[0.25, 0.75]);
    for _ in 0..2000 {
        let x = sample_categorical(&d.probs(), &mut rng) as u32;
        assert!(matches!(Rrs.verify(&[x], &d, &d, &mut rng), LevelOutcome::Accept { pos: 0 }));
    }
}

/// process_logits + nucleus filtering invariants over random logits: the
/// kept set is always a probability-sorted prefix and renormalizes to 1.
#[test]
fn prop_nucleus_keeps_top_mass() {
    let mut rng = Rng::seed_from_u64(77);
    for _ in 0..100 {
        let n = 4 + rng.gen_range(60);
        let logits: Vec<f32> = (0..n).map(|_| (rng.gen_f64() * 8.0 - 4.0) as f32).collect();
        let top_p = 0.5 + rng.gen_f64() * 0.45;
        let lp = process_logits(&logits, 1.0, top_p as f32);
        let full = process_logits(&logits, 1.0, 1.0);
        let probs = lp.probs();
        let sum: f64 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        // kept mass under the unfiltered distribution reaches top_p
        let kept_mass: f64 = full
            .probs()
            .iter()
            .zip(&lp.0)
            .filter(|(_, &l)| l.is_finite())
            .map(|(&p, _)| p)
            .sum();
        assert!(kept_mass >= top_p - 1e-9, "kept {kept_mass} < {top_p}");
        // every kept token is at least as probable as every dropped one
        let min_kept = full
            .0
            .iter()
            .zip(&lp.0)
            .filter(|(_, &l)| l.is_finite())
            .map(|(&f, _)| f)
            .fold(f64::INFINITY, f64::min);
        let max_dropped = full
            .0
            .iter()
            .zip(&lp.0)
            .filter(|(_, &l)| !l.is_finite())
            .map(|(&f, _)| f)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(min_kept >= max_dropped - 1e-12);
    }
}
