//! Fault-injection chaos tier: the engine under a seeded [`ChaosLm`]
//! fault schedule — transient and persistent eval faults, resume-path
//! failures, latency spikes — combined with enforced deadlines and
//! client cancellation, over the same undersized paged pool the soak
//! suite uses to force preemption churn.
//!
//! Invariants asserted:
//! * no deadlock — every request reaches a terminal state (watchdog
//!   timeout per receive turns a hang into a failure);
//! * exactly one terminal event per request — a `Done` or a typed
//!   `Error`, never both, never two;
//! * blast-radius isolation — a fused-batch eval fault fails only the
//!   poisoned request; co-batched requests stream bit-identical to a
//!   fault-free reference run;
//! * bounded retry — transient faults retry with deterministic backoff
//!   and the retried streams are bit-identical to the reference
//!   (round-start RNG snapshots make replays invisible);
//! * typed terminal errors — persistent faults, shed deadlines and
//!   cancellations each surface their own [`ErrorKind`];
//! * zero leaked KV blocks after the engine drains.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use rsd::bench::harness;
use rsd::chaos::{damage_spill_files, ChaosConfig, ChaosLm, FaultPlan, SpillDamage};
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig, SamplingPatch};
use rsd::coordinator::engine::{spawn, CancelRegistry, Engine, Event, Request};
use rsd::coordinator::errors::{EngineError, ErrorKind};
use rsd::coordinator::metrics::{Metrics, Snapshot};
use rsd::decode::DecodeStats;
use rsd::kvcache::KvConfig;
use rsd::llm::Llm;
use rsd::sim::SimLm;
use rsd::trace::export::chrome_trace;
use rsd::trace::{TraceEvent, Tracer};
use rsd::util::json::Json;
use rsd::util::Rng;

const VOCAB: usize = 32;
const N_REQUESTS: u64 = 200;
const SIM_SEED: u64 = 17;
const ENGINE_SEED: u64 = 99;
const PLAN_SEED: u64 = 4242;

/// Requests cancelled right after submission: low priority and deep in
/// the queue, so they are still queued when the mark lands.
const CANCEL_IDS: std::ops::RangeInclusive<u64> = 180..=185;

/// One pre-generated request, so the chaos run and the fault-free
/// reference run submit byte-identical workloads.
#[derive(Clone)]
struct Spec {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    decoder: Option<DecoderConfig>,
    sampling: Option<SamplingPatch>,
    priority: u8,
    deadline_ms: Option<u64>,
}

fn is_deadline_victim(id: u64) -> bool {
    id % 13 == 5
}

/// Seeded-random workload, mirroring the soak generator (adaptive
/// decoders excluded: their tree shapes depend on the shared estimator
/// and scheduling, which would break bit-identity). Differences from
/// the soak: requests with `id % 13 == 5` carry an already-hopeless
/// 1 ms deadline (the chaos engine enforces deadlines, the reference
/// treats them as scheduling hints), and the cancellation victims are
/// pinned to priority 0 so they cannot be admitted before the cancel
/// mark lands.
fn build_workload(seed: u64) -> Vec<Spec> {
    let mut rng = Rng::seed_from_u64(seed);
    let decoders: [Option<DecoderConfig>; 6] = [
        None, // engine default (rsd-s:3x3)
        Some(DecoderConfig::Ar),
        Some(DecoderConfig::Sd { l: 3 }),
        Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
        Some(DecoderConfig::RsdS { w: 3, l: 2 }),
        Some(DecoderConfig::SpecTr { k: 2, l: 2 }),
    ];
    (0..N_REQUESTS)
        .map(|id| {
            let prompt_len = 1 + rng.gen_range(20);
            let prompt: Vec<u32> =
                (0..prompt_len).map(|_| rng.gen_range(VOCAB) as u32).collect();
            let max_new = 1 + rng.gen_range(12);
            let decoder = decoders[rng.gen_range(decoders.len())].clone();
            let sampling = if rng.gen_range(4) == 0 {
                Some(SamplingPatch {
                    stop: Some(vec![rng.gen_range(VOCAB) as u32]),
                    ..Default::default()
                })
            } else {
                None
            };
            let priority =
                if CANCEL_IDS.contains(&id) { 0 } else { rng.gen_range(3) as u8 };
            let deadline_ms = if is_deadline_victim(id) { Some(1) } else { None };
            Spec { id, prompt, max_new, decoder, sampling, priority, deadline_ms }
        })
        .collect()
}

/// Terminal outcome of one request.
#[derive(Debug)]
enum Outcome {
    Done(Vec<u32>, DecodeStats),
    Fail(Vec<u32>, EngineError),
}

impl Outcome {
    fn stream(&self) -> &[u32] {
        match self {
            Outcome::Done(t, _) | Outcome::Fail(t, _) => t,
        }
    }
}

/// Submit the workload, optionally cancel `cancel_ids` right after
/// submission, drain every receiver to its terminal event (watchdog
/// per receive), and — after the engine exits — verify each response
/// channel is closed with nothing after the terminal event.
fn run_workload<T, D>(
    target: T,
    draft: D,
    cfg: EngineConfig,
    specs: &[Spec],
    cancel_ids: &[u64],
) -> (Vec<Outcome>, Snapshot, Vec<TraceEvent>)
where
    T: Llm + Send + 'static,
    D: Llm + Send + 'static,
    T::Session: Send,
    D::Session: Send,
{
    let trace = Tracer::new(cfg.trace_events);
    let cancels = CancelRegistry::default();
    let engine =
        Engine::with_telemetry(target, draft, cfg, Arc::new(Metrics::default()), trace.clone())
            .with_cancels(cancels.clone());
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for s in specs {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: s.id,
            prompt: s.prompt.clone(),
            max_new: s.max_new,
            decoder: s.decoder.clone(),
            sampling: s.sampling.clone(),
            priority: s.priority,
            deadline_ms: s.deadline_ms,
            resp: rtx,
        })
        .unwrap();
        receivers.push((s.id, rrx));
    }
    for &id in cancel_ids {
        cancels.request(id);
    }
    drop(tx);
    let mut results = Vec::new();
    for (id, rrx) in &receivers {
        let mut toks = Vec::new();
        loop {
            match rrx.recv_timeout(Duration::from_secs(180)) {
                Ok(Event::Tokens(t)) => toks.extend(t),
                Ok(Event::Done(r)) => {
                    results.push(Outcome::Done(std::mem::take(&mut toks), r.stats));
                    break;
                }
                Ok(Event::Error(e)) => {
                    results.push(Outcome::Fail(std::mem::take(&mut toks), e));
                    break;
                }
                Err(e) => panic!("request {id} starved or engine deadlocked: {e}"),
            }
        }
    }
    let snap = handle.join().unwrap().snapshot();
    // Engine gone -> every sender dropped. A channel still holding an
    // event means a request received something AFTER its terminal
    // event; a non-disconnected channel means a leaked sender.
    for (id, rrx) in &receivers {
        match rrx.try_recv() {
            Err(mpsc::TryRecvError::Disconnected) => {}
            Ok(ev) => panic!("request {id}: event after terminal state: {ev:?}"),
            Err(mpsc::TryRecvError::Empty) => {
                panic!("request {id}: response sender leaked past engine exit")
            }
        }
    }
    (results, snap, trace.snapshot())
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        max_concurrency: 6,
        max_queue: 256,
        default_max_tokens: 8,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.6, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: ENGINE_SEED,
        fused: true,
        ..EngineConfig::default()
    }
}

/// A small, all-defaults workload for the focused fault tests: three
/// fused co-batched requests, fixed-length prompts (so resume-hint
/// thresholds can separate admissions from resumes), no deadlines.
fn trio() -> Vec<Spec> {
    (0..3u64)
        .map(|id| Spec {
            id,
            prompt: vec![1 + id as u32, 7, 3, 9],
            max_new: 10,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
        })
        .collect()
}

fn trio_cfg() -> EngineConfig {
    EngineConfig { max_concurrency: 3, ..base_cfg() }
}

/// Fault-free reference streams for a workload: dense substrate,
/// unfused, no wrapper.
fn reference_streams(specs: &[Spec], cfg: EngineConfig) -> Vec<Vec<u32>> {
    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let (res, snap, _) =
        run_workload(t, d, EngineConfig { fused: false, ..cfg }, specs, &[]);
    assert_eq!(snap.failed, 0, "reference run must be clean");
    res.into_iter()
        .map(|o| match o {
            Outcome::Done(t, _) => t,
            Outcome::Fail(_, e) => panic!("reference run failed: {e}"),
        })
        .collect()
}

/// Regression for the blast-radius fix: a persistent eval fault inside
/// a fused batch must fail ONLY the poisoned request. Before the
/// per-group re-drive, the fused `eval_batch_into` error failed every
/// co-batched request.
#[test]
fn fused_eval_fault_fails_only_the_poisoned_request() {
    let specs = trio();
    let reference = reference_streams(&specs, trio_cfg());

    // Target sessions are opened in admission order: session 1 belongs
    // to request id 1.
    let plan = FaultPlan {
        persistent_sessions: [1u64].into_iter().collect(),
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let chaos = ChaosLm::new(t, plan);
    let trips = chaos.clone();
    let (res, snap, _) = run_workload(chaos, d, trio_cfg(), &specs, &[]);

    assert!(trips.trips().persistent >= 1, "the persistent fault never fired");
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.retries, 0, "persistent faults must not be retried");
    match &res[1] {
        Outcome::Fail(toks, e) => {
            assert_eq!(e.kind, ErrorKind::EvalPersistent, "{e}");
            assert!(!e.retryable, "{e}");
            assert!(toks.is_empty(), "poisoned request must not stream: {toks:?}");
        }
        other => panic!("request 1 should have failed, got {other:?}"),
    }
    for i in [0usize, 2] {
        assert_eq!(
            res[i].stream(),
            &reference[i][..],
            "request {i}: co-batched healthy stream diverged from reference"
        );
    }
}

/// Transient faults engage the bounded-retry path: abort the round,
/// park, resume into a fresh session (which clears the fault), and
/// replay from the round-start RNG snapshot — so every stream is
/// bit-identical to the fault-free reference.
#[test]
fn transient_fault_retries_to_bit_identical_completion() {
    let specs = trio();
    let reference = reference_streams(&specs, trio_cfg());

    let plan = FaultPlan {
        transient_sessions: [1u64].into_iter().collect(),
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let chaos = ChaosLm::new(t, plan);
    let trips = chaos.clone();
    let (res, snap, _) = run_workload(chaos, d, trio_cfg(), &specs, &[]);

    assert!(trips.trips().transient >= 1, "the transient fault never fired");
    assert_eq!(snap.completed, 3, "transient faults must not be terminal");
    assert_eq!(snap.failed, 0);
    assert!(snap.retries >= 1, "retry machinery never engaged");
    for (i, (out, want)) in res.iter().zip(&reference).enumerate() {
        assert_eq!(
            out.stream(),
            &want[..],
            "request {i}: stream diverged across a transient-fault retry"
        );
    }
}

/// A transient fault that never clears exhausts the per-request retry
/// budget and surfaces as a typed `RetriesExhausted` terminal error;
/// the co-batched requests still finish on-reference.
#[test]
fn unclearing_transient_fault_exhausts_the_retry_budget() {
    let specs = trio();
    let reference = reference_streams(&specs, trio_cfg());

    // Poison request 1's initial session AND every session a retry
    // could resume into (retries open fresh, monotonically increasing
    // ids), so the fault survives each suspend/resume cycle.
    let plan = FaultPlan {
        transient_sessions: (1u64..64).collect(),
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let chaos = ChaosLm::new(t, plan);
    let cfg = EngineConfig { retry_budget: 2, retry_backoff_rounds: 1, ..trio_cfg() };
    let (res, snap, _) = run_workload(chaos, d, cfg, &specs, &[]);

    assert_eq!(snap.completed, 1, "only the fault-free request 0 completes");
    assert_eq!(snap.failed, 2);
    assert_eq!(snap.retries, 4, "two victims x retry budget of 2");
    for i in [1usize, 2] {
        match &res[i] {
            Outcome::Fail(_, e) => {
                assert_eq!(e.kind, ErrorKind::RetriesExhausted, "request {i}: {e}");
                assert!(!e.retryable, "exhaustion is terminal: {e}");
                assert!(
                    e.to_string().contains("retry budget (2) exhausted"),
                    "request {i}: {e}"
                );
            }
            other => panic!("request {i} should have exhausted retries, got {other:?}"),
        }
    }
    assert_eq!(res[0].stream(), &reference[0][..], "request 0 diverged");
}

/// Satellite: a retryable failure while ADMITTING a request (the
/// stepper's initial `begin_with_prefix` hits an exhausted pool) must
/// requeue the request — with backoff, against the retry budget — not
/// drop it.
#[test]
fn admission_pool_exhaustion_requeues_the_request() {
    let specs = trio();
    let reference = reference_streams(&specs, trio_cfg());

    // hint_min 0: every begin_with_prefix qualifies, so the fault
    // budget of 1 is spent on the very first admission attempt.
    let plan = FaultPlan {
        resume_faults: 1,
        resume_hint_min: 0,
        resume_retryable: true,
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair(SIM_SEED, 0.8, VOCAB);
    let chaos = ChaosLm::new(t, plan);
    let trips = chaos.clone();
    let (res, snap, _) = run_workload(chaos, d, trio_cfg(), &specs, &[]);

    assert_eq!(trips.trips().resume, 1, "the admission fault never fired");
    assert_eq!(snap.completed, 3, "a retryable admission failure must not drop");
    assert_eq!(snap.failed, 0);
    assert!(snap.retries >= 1, "requeue must count as a retry");
    for (i, (out, want)) in res.iter().zip(&reference).enumerate() {
        assert_eq!(out.stream(), &want[..], "request {i}: stream diverged");
    }
}

/// AR-only trio for the resume-fault tests: AR sessions grow one slot
/// per round, so a 40-slot pool admits all three, then provably runs
/// out mid-generation — forcing a preemption whose victim has
/// committed tokens. Its resume `begin_with_prefix` hint (prompt +
/// generated) is therefore longer than any prompt, which is what lets
/// `resume_hint_min` target resumes exclusively.
fn ar_trio() -> Vec<Spec> {
    (0..3u64)
        .map(|id| Spec {
            id,
            prompt: vec![1 + id as u32, 7, 3, 9],
            max_new: 16,
            decoder: Some(DecoderConfig::Ar),
            sampling: None,
            priority: 0,
            deadline_ms: None,
        })
        .collect()
}

/// Satellite: resume-path failures after a mid-flight park. Retryable
/// variant: the victim re-parks, retries, completes bit-identically.
#[test]
fn retryable_resume_failure_reparks_and_completes() {
    let specs = ar_trio();
    let cfg = trio_cfg();
    let reference = reference_streams(&specs, cfg.clone());

    // 3 AR target sessions (prompt 4 + up to 16 generated) share 40
    // slots: growth past the pool forces preemption mid-generation.
    let kv = KvConfig { num_blocks: 10, block_size: 4, share: true };
    let plan = FaultPlan {
        resume_faults: 1,
        resume_hint_min: 4, // == prompt length: only resumes qualify
        resume_retryable: true,
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair_paged(SIM_SEED, 0.8, VOCAB, kv);
    let pool = t.kv_pool().expect("paged sim").clone();
    let chaos = ChaosLm::new(t, plan);
    let trips = chaos.clone();
    let (res, snap, _) = run_workload(chaos, d, cfg, &specs, &[]);

    assert!(snap.preemptions >= 1, "pool never forced a preemption");
    assert_eq!(trips.trips().resume, 1, "the resume fault never fired");
    assert_eq!(snap.completed, 3, "a retryable resume failure must not drop");
    assert_eq!(snap.failed, 0);
    assert!(snap.retries >= 1, "resume requeue must count as a retry");
    assert_eq!(pool.status().blocks_in_use(), 0, "leaked KV blocks");
    for (i, (out, want)) in res.iter().zip(&reference).enumerate() {
        assert_eq!(out.stream(), &want[..], "request {i}: stream diverged");
    }
}

/// Satellite: the terminal variant — a non-retryable resume failure
/// produces exactly one typed terminal error for the victim; everyone
/// else finishes on-reference and no blocks leak.
#[test]
fn terminal_resume_failure_is_a_typed_error() {
    let specs = ar_trio();
    let cfg = trio_cfg();
    let reference = reference_streams(&specs, cfg.clone());

    let kv = KvConfig { num_blocks: 10, block_size: 4, share: true };
    let plan = FaultPlan {
        resume_faults: 1,
        resume_hint_min: 4,
        resume_retryable: false,
        ..FaultPlan::none()
    };
    let (t, d) = SimLm::pair_paged(SIM_SEED, 0.8, VOCAB, kv);
    let pool = t.kv_pool().expect("paged sim").clone();
    let chaos = ChaosLm::new(t, plan);
    let trips = chaos.clone();
    let (res, snap, _) = run_workload(chaos, d, cfg, &specs, &[]);

    assert_eq!(trips.trips().resume, 1, "the resume fault never fired");
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.failed, 1);
    assert_eq!(pool.status().blocks_in_use(), 0, "leaked KV blocks");
    let mut failed = 0usize;
    for (i, out) in res.iter().enumerate() {
        match out {
            Outcome::Fail(_, e) => {
                failed += 1;
                assert_eq!(e.kind, ErrorKind::EvalPersistent, "request {i}: {e}");
                assert!(!e.retryable, "request {i}: {e}");
            }
            Outcome::Done(toks, _) => {
                assert_eq!(toks, &reference[i], "request {i}: survivor diverged");
            }
        }
    }
    assert_eq!(failed, 1, "exactly one victim");
}

/// The 200-request chaos soak (see module docs): seeded fault plan +
/// enforced deadlines + client cancellation over the preemption-heavy
/// undersized pool. Every request terminates exactly once; every
/// completed stream is bit-identical to the fault-free reference; the
/// terminal-error population reconciles with the metrics counters; no
/// KV block leaks. Dumps the fault schedule and the flight-recorder
/// journal for CI artifacts.
#[test]
fn chaos_soak_is_isolated_deterministic_and_leak_free() {
    let specs = build_workload(2024);
    let cancel_ids: Vec<u64> = CANCEL_IDS.collect();

    // Fault universe [0, 128): ~200 admissions open at least that many
    // target sessions, so every planned fault id is guaranteed to be
    // exercised.
    let plan = FaultPlan::seeded(
        PLAN_SEED,
        &ChaosConfig {
            sessions: 128,
            transient: 5,
            persistent: 3,
            spikes: 8,
            spike_calls: 2_000,
            spike_spin: 2_000,
            resume_faults: 0, // resume faults have dedicated tests above
            resume_hint_min: usize::MAX,
            resume_retryable: true,
        },
    );
    let plan_doc = plan.to_json();

    let kv = KvConfig { num_blocks: 24, block_size: 8, share: true };
    let (t, d) = SimLm::pair_paged(SIM_SEED, 0.8, VOCAB, kv);
    let pool = t.kv_pool().expect("paged sim").clone();
    let chaos = ChaosLm::new(t, plan);
    let trips_handle = chaos.clone();
    let cfg = EngineConfig { enforce_deadlines: true, trace_events: 4096, ..base_cfg() };
    let (res, snap, events) = run_workload(chaos, d, cfg, &specs, &cancel_ids);

    let reference = reference_streams(&specs, base_cfg());

    // The plan actually bit: both fault classes fired.
    let trips = trips_handle.trips();
    assert!(trips.transient >= 1, "no transient fault fired: {trips:?}");
    assert!(trips.persistent >= 1, "no persistent fault fired: {trips:?}");

    // Terminal accounting: every request lands in exactly one bucket,
    // and the per-request typed errors reconcile with the counters.
    let (mut cancelled, mut shed, mut failed, mut completed) = (0u64, 0u64, 0u64, 0u64);
    for (spec, out) in specs.iter().zip(&res) {
        match out {
            Outcome::Done(toks, stats) => {
                completed += 1;
                assert_eq!(stats.generated, toks.len(), "id {}: stats vs stream", spec.id);
                assert!(toks.len() <= spec.max_new, "id {}: overlong stream", spec.id);
            }
            Outcome::Fail(_, e) => match e.kind {
                ErrorKind::Cancelled => {
                    cancelled += 1;
                    assert!(cancel_ids.contains(&spec.id), "spurious cancel on {}", spec.id);
                }
                ErrorKind::DeadlineExpired => {
                    shed += 1;
                    assert!(e.retryable, "shed must be retryable: {e}");
                    assert!(is_deadline_victim(spec.id), "spurious shed on {}", spec.id);
                }
                ErrorKind::EvalPersistent | ErrorKind::RetriesExhausted => {
                    failed += 1;
                    assert!(!e.retryable, "terminal fault must not be retryable: {e}");
                }
                other => panic!("id {}: unexpected terminal kind {other:?}: {e}", spec.id),
            },
        }
    }
    assert_eq!(completed + failed + shed + cancelled, N_REQUESTS);
    assert_eq!(snap.completed, completed);
    assert_eq!(snap.failed, failed);
    assert_eq!(snap.shed, shed);
    assert_eq!(snap.cancelled, cancelled);
    assert_eq!(
        cancelled,
        cancel_ids.len() as u64,
        "every queued cancel victim gets exactly one Cancelled terminal"
    );
    assert!(shed >= 1, "no hopeless deadline was shed");
    assert!(failed >= 1, "persistent faults fired but nothing failed");
    assert!(snap.retries >= 1, "transient faults fired but nothing retried");
    assert_eq!(snap.rejected, 0, "queue 256 must never overflow");

    // Blast-radius + retry transparency: every request the faults did
    // NOT kill streams bit-identically to the fault-free reference —
    // including requests that were co-batched with a poisoned session
    // and requests that replayed rounds after a transient retry.
    let mut compared = 0usize;
    for ((spec, out), want) in specs.iter().zip(&res).zip(&reference) {
        if let Outcome::Done(toks, _) = out {
            compared += 1;
            assert_eq!(
                toks, want,
                "id {}: stream diverged from fault-free reference",
                spec.id
            );
        }
    }
    assert!(compared as u64 == completed && completed >= N_REQUESTS / 2);

    // Resource hygiene: the pool drained completely despite failures,
    // sheds, cancels and preemption churn.
    assert_eq!(pool.status().blocks_in_use(), 0, "leaked KV blocks");
    assert!(snap.preemptions >= 1, "undersized pool never preempted");

    // Flight recorder saw the run; dump schedule + journal for CI.
    assert!(!events.is_empty(), "tracing was enabled but recorded nothing");
    assert!(events.windows(2).all(|w| w[1].seq == w[0].seq + 1), "seq gap/tear");
    let doc = Json::obj(vec![("trace", chrome_trace(&events))]);
    std::fs::write(harness::snapshot_path("TRACE_chaos.json"), format!("{doc}\n"))
        .expect("write TRACE_chaos.json");
    std::fs::write(harness::snapshot_path("FAULTS_chaos.json"), format!("{plan_doc}\n"))
        .expect("write FAULTS_chaos.json");
}

/// Corrupt-spill soak: the 200-request workload over the undersized
/// pool WITH a cold tier, run twice over the same store — and between
/// the runs every spilled block file is damaged (bit flips on the
/// target store, truncation on the draft store). Invariants: per-
/// request streams are bit-identical across cold-off, cold-on and
/// corrupted-cold runs; corruption surfaces only as `kv_cold_corrupt`
/// telemetry (zero failures, zero leaked blocks); the [`ChaosLm`]
/// wrapper forwards the cold seams transparently.
#[test]
fn corrupt_spill_soak_degrades_cleanly_and_stays_bit_identical() {
    let specs = build_workload(2024);
    let dir = std::env::temp_dir().join("rsd-chaos-coldsoak");
    let _ = std::fs::remove_dir_all(&dir);
    let kv = KvConfig { num_blocks: 24, block_size: 8, share: true };
    // base_cfg leaves enforce_deadlines off, and no cancels are issued:
    // deadline fields are scheduling hints here, so all three runs are
    // fully deterministic and comparable request by request.
    let reference = reference_streams(&specs, base_cfg());

    let run_cold = |expect_clean_store: bool| {
        let (t, d) = SimLm::pair_paged_cold(SIM_SEED, 0.8, VOCAB, kv, &dir, 512)
            .expect("cold tier attach");
        let pool = t.kv_pool().expect("paged sim").clone();
        if expect_clean_store {
            assert_eq!(pool.stats().cold_corrupt, 0, "store should boot clean");
        } else {
            let s = pool.stats();
            assert!(s.cold_corrupt > 0, "damage went undetected at boot: {s:?}");
            assert_eq!(s.cold_hits, 0, "a damaged block revived: {s:?}");
        }
        // wrap in a fault-free ChaosLm so the soak also covers the
        // wrapper's forwarding of the cold seams (export/import/peek/
        // persist) the engine drives
        let chaos = ChaosLm::new(t, FaultPlan::none());
        let (res, snap, _) = run_workload(chaos, d, base_cfg(), &specs, &[]);
        assert_eq!(snap.completed, N_REQUESTS, "cold tier must never fail a request");
        assert_eq!(snap.failed, 0);
        assert!(snap.preemptions >= 1, "undersized pool never preempted");
        assert_eq!(pool.status().blocks_in_use(), 0, "leaked KV blocks");
        let streams: Vec<Vec<u32>> = res
            .into_iter()
            .map(|o| match o {
                Outcome::Done(t, _) => t,
                Outcome::Fail(_, e) => panic!("cold-soak request failed: {e}"),
            })
            .collect();
        assert_eq!(streams, reference, "cold tier must be token-invisible");
        snap
    };

    let snap1 = run_cold(true);
    assert!(snap1.kv_cold_spills > 0, "evictions + shutdown must spill");

    let hit_t = damage_spill_files(&dir.join("target"), 7, usize::MAX, SpillDamage::CorruptByte);
    let hit_d = damage_spill_files(&dir.join("draft"), 8, usize::MAX, SpillDamage::Truncate);
    assert!(!hit_t.is_empty() && !hit_d.is_empty(), "no spill files to damage");

    let snap2 = run_cold(false);
    assert!(snap2.kv_cold_corrupt > 0, "degradation must be counted");
    assert_eq!(snap2.completed, N_REQUESTS);

    let doc = Json::obj(vec![
        ("damaged_target_files", hit_t.len().into()),
        ("damaged_draft_files", hit_d.len().into()),
        ("run1_cold_spills", (snap1.kv_cold_spills as usize).into()),
        ("run1_cold_hits", (snap1.kv_cold_hits as usize).into()),
        ("run2_cold_corrupt", (snap2.kv_cold_corrupt as usize).into()),
        ("run2_cold_hits", (snap2.kv_cold_hits as usize).into()),
        ("requests", (N_REQUESTS as usize).into()),
    ]);
    std::fs::write(harness::snapshot_path("COLD_chaos.json"), format!("{doc}\n"))
        .expect("write COLD_chaos.json");
    let _ = std::fs::remove_dir_all(&dir);
}
