//! SATELLITE: accuracy and contract tests for the vectorizable math
//! kernels in `rsd::sampling::kernels`.
//!
//! Two families of assertion:
//!
//! * **bit-exactness** where the kernel claims it (the batched Gumbel
//!   map vs the scalar transform, `max` vs the serial fold,
//!   `sub_from_unfiltered` vs the branchy loop);
//! * **ULP / tolerance contracts** where the kernel documents a
//!   deviation (polynomial `exp`/`ln` vs libm, chunked sums vs serial
//!   folds, `log_normalize` vs a naive serial libm reference).
//!
//! Tolerances here are deliberately looser than the measured worst cases
//! (~1–2 ULP for the polynomials) so the tests pin the *contract*, not
//! one libm build.

use rsd::sampling::kernels;
use rsd::sampling::{log_normalize, NEG_INF};
use rsd::util::Rng;

fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        got.abs()
    } else {
        (got - want).abs() / want.abs()
    }
}

#[test]
fn exp_poly_matches_libm_over_logprob_domain() {
    // dense deterministic sweep + random points over the domain the
    // sampling code exercises (log-probs and softmax shifts)
    let mut worst = 0.0f64;
    let mut i = 0;
    let mut x = -700.0;
    while x <= 709.0 {
        let e = rel_err(kernels::exp(x), x.exp());
        if e > worst {
            worst = e;
        }
        // irregular stride so we do not sample only near-integer reductions
        x += 0.137 + 0.011 * ((i % 7) as f64);
        i += 1;
    }
    let mut rng = Rng::seed_from_u64(42);
    for _ in 0..200_000 {
        let x = -700.0 + 1409.0 * rng.gen_f64();
        let e = rel_err(kernels::exp(x), x.exp());
        if e > worst {
            worst = e;
        }
    }
    // measured worst case ~1 ULP (2.3e-16); contract allows ~4.5 ULP
    assert!(worst < 1e-15, "exp worst relative error {worst:e}");
}

#[test]
fn exp_poly_specials_and_flush_contract() {
    assert_eq!(kernels::exp(0.0), 1.0);
    assert_eq!(kernels::exp(0.0).to_bits(), 1.0f64.to_bits());
    assert_eq!(kernels::exp(NEG_INF), 0.0);
    assert_eq!(kernels::exp(f64::INFINITY), f64::INFINITY);
    assert!(kernels::exp(f64::NAN).is_nan());
    // documented deviation from libm: flush-to-zero below -708 (libm
    // returns subnormals down to ~-745) ...
    assert_eq!(kernels::exp(-709.0), 0.0);
    assert_eq!(kernels::exp(-5000.0), 0.0);
    // ... and overflow from ~709.44 (libm from ~709.78)
    assert_eq!(kernels::exp(710.0), f64::INFINITY);
    assert_eq!(kernels::exp(1e300), f64::INFINITY);
    // masked-token path: exp stays exactly 0, never a subnormal
    assert_eq!(kernels::exp(-708.5).to_bits(), 0.0f64.to_bits());
}

#[test]
fn ln_poly_matches_libm_over_positive_domain() {
    let mut rng = Rng::seed_from_u64(7);
    let mut worst = 0.0f64;
    // normals across the full exponent range: random mantissa in [1, 2)
    // scaled by 2^e
    for _ in 0..200_000 {
        let m = 1.0 + rng.gen_f64();
        let e = rng.gen_range(2001) as i32 - 1000;
        let x = m * f64::powi(2.0, e);
        let err = rel_err(kernels::ln(x), x.ln());
        if err > worst {
            worst = err;
        }
    }
    // the cancellation region near 1 (atanh form keeps relative accuracy)
    for k in 1..=10_000i64 {
        for x in [1.0 + k as f64 * 1e-12, 1.0 - k as f64 * 1e-12] {
            let err = rel_err(kernels::ln(x), x.ln());
            if err > worst {
                worst = err;
            }
        }
    }
    // subnormals (pre-scaled by 2^54 internally)
    for x in [5e-324, 1e-320, 1e-310, 2.2e-308] {
        let err = rel_err(kernels::ln(x), x.ln());
        if err > worst {
            worst = err;
        }
    }
    // measured worst case ~1.7 ULP (3.8e-16); contract allows ~4.5 ULP
    assert!(worst < 1e-15, "ln worst relative error {worst:e}");
}

#[test]
fn ln_poly_specials() {
    assert_eq!(kernels::ln(0.0), NEG_INF);
    assert_eq!(kernels::ln(-0.0), NEG_INF);
    assert!(kernels::ln(-1.0).is_nan());
    assert!(kernels::ln(NEG_INF).is_nan());
    assert_eq!(kernels::ln(f64::INFINITY), f64::INFINITY);
    assert!(kernels::ln(f64::NAN).is_nan());
    // exact anchor: ln(1) = +0 to the bit
    assert_eq!(kernels::ln(1.0).to_bits(), 0.0f64.to_bits());
}

#[test]
fn gumbel_map_bit_identical_to_scalar_transform() {
    // the batched slice map IS the scalar transform applied elementwise —
    // this is the keystone of the selection bit-exactness contract
    let mut rng = Rng::seed_from_u64(99);
    for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 1000] {
        let us: Vec<f64> = (0..len).map(|_| rng.gen_f64_open()).collect();
        let mut batched = us.clone();
        kernels::gumbel_map_in_place(&mut batched);
        for (i, (&b, &u)) in batched.iter().zip(&us).enumerate() {
            assert_eq!(
                b.to_bits(),
                kernels::gumbel_from_uniform(u).to_bits(),
                "len {len} elem {i}"
            );
        }
    }
    // the u = 1 edge draw (probability 2^-53): -ln(-ln(1)) = +inf, same
    // as the libm chain
    assert_eq!(kernels::gumbel_from_uniform(1.0), f64::INFINITY);
}

#[test]
fn chunked_max_equals_serial_fold_exactly() {
    let mut rng = Rng::seed_from_u64(3);
    for len in 0..=(4 * kernels::LANES + 3) {
        let mut xs: Vec<f64> = (0..len).map(|_| 20.0 * rng.gen_f64() - 10.0).collect();
        // sprinkle NaN and -inf: max must ignore NaN like f64::max does
        if len > 2 {
            xs[len / 2] = f64::NAN;
            xs[len / 3] = NEG_INF;
        }
        let serial = xs.iter().fold(NEG_INF, |a, &b| a.max(b));
        assert_eq!(kernels::max(&xs).to_bits(), serial.to_bits(), "len {len}");
    }
    assert_eq!(kernels::max(&[]), NEG_INF);
    assert_eq!(kernels::max(&[f64::NAN, f64::NAN]), NEG_INF);
}

#[test]
fn chunked_sums_match_serial_folds_within_ulp_contract() {
    let mut rng = Rng::seed_from_u64(5);
    for len in [1usize, 7, 8, 9, 35, 256, 8192, 32000] {
        let xs: Vec<f64> = (0..len).map(|_| rng.gen_f64()).collect();
        let serial: f64 = xs.iter().sum();
        assert!(rel_err(kernels::sum(&xs), serial) < 1e-12, "sum len {len}");

        let shift = 2.0;
        let serial_exp: f64 = xs.iter().map(|&x| (x - shift).exp()).sum();
        assert!(
            rel_err(kernels::sum_exp_shifted(&xs, shift), serial_exp) < 1e-12,
            "sum_exp_shifted len {len}"
        );

        let ps: Vec<f64> = (0..len).map(|_| rng.gen_f64()).collect();
        let serial_relu: f64 = xs.iter().zip(&ps).map(|(&q, &p)| (q - p).max(0.0)).sum();
        let got = kernels::sum_relu_diff(&xs, &ps);
        if serial_relu == 0.0 {
            assert_eq!(got, 0.0, "sum_relu_diff len {len}");
        } else {
            assert!(rel_err(got, serial_relu) < 1e-12, "sum_relu_diff len {len}");
        }
    }
}

#[test]
fn sub_from_unfiltered_preserves_masks_and_nan() {
    let mut lp = vec![-1.0, NEG_INF, 0.5, f64::NAN, -3.25];
    kernels::sub_from_unfiltered(&mut lp, 0.75);
    assert_eq!(lp[0], -1.75);
    assert_eq!(lp[1], NEG_INF);
    assert_eq!(lp[2], -0.25);
    assert!(lp[3].is_nan());
    assert_eq!(lp[4], -4.0);
}

#[test]
fn log_normalize_matches_naive_serial_reference_within_contract() {
    // the naive pre-PR form: serial max fold, serial libm-exp partition
    // sum, branchy subtraction
    fn naive(lp: &mut [f64]) {
        let m = lp.iter().fold(NEG_INF, |a, &b| a.max(b));
        if m == NEG_INF {
            return;
        }
        let z: f64 = lp.iter().map(|&l| (l - m).exp()).sum();
        let lz = m + z.ln();
        for l in lp.iter_mut() {
            if *l != NEG_INF {
                *l -= lz;
            }
        }
    }
    let mut rng = Rng::seed_from_u64(11);
    for len in [1usize, 2, 35, 256, 8192, 32000] {
        let base: Vec<f64> = (0..len)
            .map(|_| if rng.gen_f64() < 0.1 { NEG_INF } else { -10.0 * rng.gen_f64() })
            .collect();
        let mut a = base.clone();
        let mut b = base;
        log_normalize(&mut a);
        naive(&mut b);
        for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
            if y == NEG_INF {
                assert_eq!(x, NEG_INF, "len {len} elem {i}: mask must survive");
            } else {
                // reassociated sum + polynomial exp: values move by ULPs
                assert!((x - y).abs() < 1e-11, "len {len} elem {i}: {x} vs {y}");
            }
        }
    }
    // fully-masked rows pass through untouched in both forms
    let mut all_inf = vec![NEG_INF; 9];
    log_normalize(&mut all_inf);
    assert!(all_inf.iter().all(|&x| x == NEG_INF));
}

#[test]
fn cos_2pi_matches_libm_cosine() {
    let mut rng = Rng::seed_from_u64(13);
    let mut worst = 0.0f64;
    for _ in 0..200_000 {
        // the sim substrate feeds uniforms in [0, 1); also probe a few
        // turns out of range since the reduction is generic
        let u = 3.0 * rng.gen_f64() - 1.0;
        let got = kernels::cos_2pi(u);
        let want = (2.0 * std::f64::consts::PI * u).cos();
        let err = (got - want).abs();
        if err > worst {
            worst = err;
        }
    }
    // validated absolute error <= ~4e-15 over [0, 1]
    assert!(worst < 1e-12, "cos_2pi worst absolute error {worst:e}");
    assert_eq!(kernels::cos_2pi(0.0), 1.0);
    assert!((kernels::cos_2pi(0.5) + 1.0).abs() < 1e-14);
    assert!(kernels::cos_2pi(0.25).abs() < 1e-14);
}
