//! Adversarial tensorfile suite: the cold KV tier trusts this layer
//! with persisted cache state, so `tensorfile::load` must survive
//! arbitrary header/byte corruption — truncation, overflowing offsets,
//! aliased ranges, garbage dtypes — with a clean `Err`, never a panic
//! and never a bogus tensor. Mirrors the seeded structure-aware fuzz
//! idiom of `tests/protocol.rs`, plus cross-writer round-trips against
//! the `python/compile/tensorfile.py` layout (no per-tensor checksums).

use std::io::Write;
use std::path::{Path, PathBuf};

use rsd::tensorfile::{crc32, load, save, Dtype, Tensor, Tensors};
use rsd::util::json::Json;
use rsd::util::Rng;

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tf_fuzz_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write `[u64 LE header_len][header][data]` with an explicit (possibly
/// lying) header length — the knob most corruptions turn.
fn write_raw(path: &Path, hlen: u64, header: &[u8], data: &[u8]) {
    let mut f = std::fs::File::create(path).unwrap();
    f.write_all(&hlen.to_le_bytes()).unwrap();
    f.write_all(header).unwrap();
    f.write_all(data).unwrap();
}

/// The exact layout `python/compile/tensorfile.py` emits: sorted keys,
/// `", "` / `": "` separators, NO `crc32` fields.
fn python_style_file(path: &Path) -> Vec<f32> {
    let vals: Vec<f32> = (0..12).map(|i| i as f32 * 0.75 - 3.0).collect();
    let mut data = Vec::new();
    for v in &vals {
        data.extend_from_slice(&v.to_le_bytes());
    }
    let ints: [i32; 3] = [-1, 0, 7];
    for v in ints {
        data.extend_from_slice(&v.to_le_bytes());
    }
    let header = r#"{"idx": {"dtype": "i32", "nbytes": 12, "offset": 48, "shape": [3]}, "w": {"dtype": "f32", "nbytes": 48, "offset": 0, "shape": [3, 4]}}"#;
    write_raw(path, header.len() as u64, header.as_bytes(), &data);
    vals
}

/// Rust reads the python writer's output (legacy weight files carry no
/// checksums and must stay loadable verbatim).
#[test]
fn loads_python_writer_layout() {
    let dir = tdir("py");
    let p = dir.join("weights.tensors");
    let vals = python_style_file(&p);
    let ts = load(&p).unwrap();
    assert_eq!(ts.len(), 2);
    assert_eq!(ts["w"].shape, vec![3, 4]);
    assert_eq!(ts["w"].as_f32().unwrap(), vals);
    assert_eq!(ts["idx"].dtype, Dtype::I32);
    assert_eq!(ts["idx"].data.len(), 12);
}

/// The Rust writer's output stays readable by the python reader's
/// contract: u64 LE header length, JSON header whose per-tensor
/// `dtype`/`shape`/`offset`/`nbytes` fields slice the data section
/// (extra fields like `crc32` are ignored by the python side).
#[test]
fn rust_writer_honors_the_python_reader_contract() {
    let dir = tdir("contract");
    let p = dir.join("out.tensors");
    let mut ts = Tensors::new();
    let vals = [0.5f32, -1.5, 2.0, 1e-20];
    ts.insert("a".into(), Tensor::from_f32(vec![4], &vals).unwrap());
    ts.insert("z".into(), Tensor::from_f32(vec![1, 2], &[9.0, 8.0]).unwrap());
    save(&p, &ts).unwrap();

    // replay the python reader: struct.unpack("<Q"), json.loads, slice
    let bytes = std::fs::read(&p).unwrap();
    let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
    let header = Json::parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
    let data = &bytes[8 + hlen..];
    for (name, want) in [("a", &vals[..]), ("z", &[9.0f32, 8.0][..])] {
        let meta = header.get(name).unwrap();
        assert_eq!(meta.str_field("dtype").unwrap(), "f32");
        let off = meta.usize_field("offset").unwrap();
        let nbytes = meta.usize_field("nbytes").unwrap();
        let got: Vec<f32> = data[off..off + nbytes]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(got, want, "tensor {name} bytes diverge from the header plan");
        // the checksum the python reader ignores is present and correct
        let crc = meta.get("crc32").unwrap().as_f64().unwrap() as u32;
        assert_eq!(crc, crc32(&data[off..off + nbytes]));
    }
    // and the Rust reader round-trips its own writer bit-exactly
    let back = load(&p).unwrap();
    assert_eq!(back["a"].as_f32().unwrap(), vals);
}

const DTYPES: &[&str] = &["f32", "i32", "f64", "bf16", "", "F32", "junk"];

/// Extreme header field values (as raw JSON snippets).
const NUMS: &[&str] = &[
    "0",
    "1",
    "4",
    "16",
    "24",
    "-1",
    "-16",
    "18446744073709551615",
    "18446744073709551616",
    "9223372036854775807",
    "4611686018427387904",
    "1e308",
    "-1e308",
    "0.5",
    "null",
    "\"16\"",
    "[16]",
];

const SHAPES: &[&str] = &[
    "[4]",
    "[2, 2]",
    "[]",
    "[0]",
    "[1, 0, 9]",
    "[4611686018427387904, 4]",
    "[4294967295, 4294967295, 4294967295]",
    "[-1]",
    "[1.5]",
    "[null]",
    "\"4\"",
    "4",
];

/// One structure-aware random header: plausible tensor entries with
/// extreme or ill-typed fields, sometimes missing fields, sometimes
/// duplicated ranges.
fn fuzz_header(rng: &mut Rng) -> String {
    let n = rng.gen_range(4);
    let entries: Vec<String> = (0..n)
        .map(|i| {
            let mut fields = Vec::new();
            if rng.gen_range(8) != 0 {
                fields.push(format!(r#""dtype": "{}""#, DTYPES[rng.gen_range(DTYPES.len())]));
            }
            if rng.gen_range(8) != 0 {
                fields.push(format!(r#""shape": {}"#, SHAPES[rng.gen_range(SHAPES.len())]));
            }
            if rng.gen_range(8) != 0 {
                fields.push(format!(r#""offset": {}"#, NUMS[rng.gen_range(NUMS.len())]));
            }
            if rng.gen_range(8) != 0 {
                fields.push(format!(r#""nbytes": {}"#, NUMS[rng.gen_range(NUMS.len())]));
            }
            if rng.gen_range(4) == 0 {
                fields.push(format!(r#""crc32": {}"#, NUMS[rng.gen_range(NUMS.len())]));
            }
            format!(r#""t{i}": {{{}}}"#, fields.join(", "))
        })
        .collect();
    format!("{{{}}}", entries.join(", "))
}

/// 2k seeded structure-aware headers through `load`: random field
/// combinations, lying header lengths, random data-section sizes. Every
/// call must return (Ok or Err) — a panic aborts the test. The control
/// group (a well-formed header every 64th round) must keep loading.
#[test]
fn header_fuzz_never_panics() {
    let dir = tdir("hdr");
    let p = dir.join("fuzz.tensors");
    let mut rng = Rng::seed_from_u64(0x7E45_0125);
    let (mut oks, mut errs) = (0usize, 0usize);
    for i in 0..2_000 {
        let header = if i % 64 == 0 {
            r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16}}"#.to_string()
        } else {
            fuzz_header(&mut rng)
        };
        let data_len = rng.gen_range(64);
        let data: Vec<u8> = (0..data_len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        // lie about the header length every 8th round
        let hlen = match rng.gen_range(8) {
            0 => rng.next_u64(),
            1 => header.len() as u64 + rng.gen_range(64) as u64,
            2 => (header.len() as u64).saturating_sub(rng.gen_range(8) as u64),
            _ => header.len() as u64,
        };
        write_raw(&p, hlen, header.as_bytes(), &data);
        match load(&p) {
            Ok(ts) => {
                oks += 1;
                // anything that loads obeys its own header plan
                for t in ts.values() {
                    assert_eq!(t.data.len(), t.element_count() * 4);
                }
            }
            Err(_) => errs += 1,
        }
    }
    assert!(oks > 0, "fuzz corpus never produced a loadable file");
    assert!(errs > 0, "fuzz corpus never produced a rejected file");
}

/// Byte-level corruption of a valid checksummed file: flip, truncate or
/// splice at seeded positions. `load` must never panic, and a payload
/// byte flip must never yield the original tensor values silently.
#[test]
fn byte_mutation_fuzz_never_panics_or_passes_corruption() {
    let dir = tdir("bytes");
    let p = dir.join("victim.tensors");
    let vals: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
    let mut ts = Tensors::new();
    ts.insert("w".into(), Tensor::from_f32(vec![32], &vals).unwrap());
    save(&p, &ts).unwrap();
    let pristine = std::fs::read(&p).unwrap();

    let mut rng = Rng::seed_from_u64(0xB17E);
    for _ in 0..2_000 {
        let mut bytes = pristine.clone();
        match rng.gen_range(3) {
            0 => bytes.truncate(rng.gen_range(bytes.len())),
            1 => {
                let i = rng.gen_range(bytes.len());
                bytes[i] ^= 1 << rng.gen_range(8);
            }
            _ => {
                let at = rng.gen_range(bytes.len() + 1);
                let ins: Vec<u8> =
                    (0..1 + rng.gen_range(8)).map(|_| (rng.next_u64() & 0xff) as u8).collect();
                bytes.splice(at..at, ins);
            }
        }
        if bytes == pristine {
            continue;
        }
        std::fs::write(&p, &bytes).unwrap();
        if let Ok(ts) = load(&p) {
            // a mutated file may still parse (e.g. the flip landed in
            // JSON whitespace), but checksummed payload bytes can never
            // silently change value
            if let Some(w) = ts.get("w") {
                if w.shape == [32] && w.dtype == Dtype::F32 {
                    assert_eq!(
                        w.as_f32().unwrap(),
                        vals,
                        "corrupted payload passed the checksum"
                    );
                }
            }
        }
    }
    // control: the pristine bytes still load
    std::fs::write(&p, &pristine).unwrap();
    assert_eq!(load(&p).unwrap()["w"].as_f32().unwrap(), vals);
}

/// Handcrafted adversarial headers the fuzzer might take a while to
/// find: overflowing `offset + nbytes`, wrapping shape products and
/// aliased tensor ranges must all reject cleanly.
#[test]
fn adversarial_headers_reject_cleanly() {
    let dir = tdir("adv");
    let p = dir.join("adv.tensors");
    let cases = [
        // offset + nbytes wraps past the bounds check
        format!(
            r#"{{"a": {{"dtype": "f32", "shape": [4], "offset": {}, "nbytes": 16}}}}"#,
            u64::MAX - 4
        ),
        // shape product wraps to a tiny nbytes
        format!(
            r#"{{"a": {{"dtype": "f32", "shape": [{}, 4], "offset": 0, "nbytes": 0}}}}"#,
            1u64 << 62
        ),
        // two tensors aliasing the same bytes
        r#"{"a": {"dtype": "f32", "shape": [4], "offset": 0, "nbytes": 16},
           "b": {"dtype": "f32", "shape": [4], "offset": 4, "nbytes": 16}}"#
            .to_string(),
        // implausible header length is rejected before allocation
        r#"{"a": 1}"#.to_string(),
    ];
    for (i, header) in cases.iter().enumerate() {
        let hlen =
            if i == cases.len() - 1 { 17 << 20 } else { header.len() as u64 };
        write_raw(&p, hlen, header.as_bytes(), &[0u8; 32]);
        assert!(load(&p).is_err(), "case {i} must reject: {header}");
    }
}
