//! Paged KV-cache integration tests on the sim substrate: radix prefix
//! sharing is token-invisible, suspend→evict→resume round-trips a
//! session losslessly, and an engine over a deliberately undersized
//! pool preempts instead of rejecting — and still completes everything
//! bit-identically.

use std::path::PathBuf;
use std::sync::mpsc;

use rsd::chaos::{damage_spill_files, SpillDamage};
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::decode::spec::{SpecStepper, StepOutcome};
use rsd::decode::{build_parts, DecodeStats};
use rsd::kvcache::KvConfig;
use rsd::llm::Llm;
use rsd::sim::SimLm;
use rsd::util::Rng;

const VOCAB: usize = 64;

fn engine_cfg(max_concurrency: usize, max_new: usize) -> EngineConfig {
    EngineConfig {
        max_concurrency,
        max_queue: 64,
        default_max_tokens: max_new,
        max_active_budget: 0,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 7,
        fused: true,
        ..EngineConfig::default()
    }
}

/// Shared 48-token system prompt + unique per-request suffix.
fn prompt_for(i: u64) -> Vec<u32> {
    let mut p: Vec<u32> = (0..48u32).map(|t| (t * 5 + 1) % VOCAB as u32).collect();
    p.extend([(7 + i) as u32 % VOCAB as u32, (3 * i) as u32 % VOCAB as u32]);
    p
}

/// Run `n` requests (mixed decoders) through one engine; returns
/// (streams in submission order, per-request done stats, metrics).
fn run_engine(
    target: SimLm,
    draft: SimLm,
    cfg: EngineConfig,
    n: u64,
    max_new: usize,
    prompts: impl Fn(u64) -> Vec<u32>,
    decoder_for: impl Fn(u64) -> Option<DecoderConfig>,
) -> (Vec<Vec<u32>>, Vec<DecodeStats>, rsd::coordinator::metrics::Snapshot) {
    let engine = Engine::new(target, draft, cfg);
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for i in 0..n {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i,
            prompt: prompts(i),
            max_new,
            decoder: decoder_for(i),
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    let mut streams = Vec::new();
    let mut stats = Vec::new();
    for (i, rrx) in receivers.into_iter().enumerate() {
        let mut toks = Vec::new();
        loop {
            match rrx.recv().expect("engine dropped request") {
                Event::Tokens(t) => toks.extend(t),
                Event::Done(r) => {
                    stats.push(r.stats);
                    break;
                }
                Event::Error(e) => panic!("request {i}: {e}"),
            }
        }
        streams.push(toks);
    }
    (streams, stats, handle.join().unwrap().snapshot())
}

fn mixed_decoder(i: u64) -> Option<DecoderConfig> {
    match i % 3 {
        0 => None, // engine default rsd-s:3x3
        1 => Some(DecoderConfig::Ar),
        _ => Some(DecoderConfig::RsdC { branches: vec![2, 2] }),
    }
}

/// Short shared prefix (one full block of 8) + unique suffix: admission
/// happily takes everyone, the memory pressure only builds as the
/// committed prefixes grow during generation.
fn short_prompt(i: u64) -> Vec<u32> {
    let mut p: Vec<u32> = (0..8u32).map(|t| (t * 5 + 1) % VOCAB as u32).collect();
    p.extend([(7 + i) as u32 % VOCAB as u32, (3 * i) as u32 % VOCAB as u32]);
    p
}

/// Property: shared-prefix batches decode bit-identical token streams
/// with sharing on, sharing off, and on the dense (non-paged) substrate
/// — same RNG draw order everywhere. Sharing must only change which
/// prefill rows get computed.
#[test]
fn prefix_sharing_is_token_invisible() {
    let n = 6u64;
    let max_new = 14;
    let paged = |share| KvConfig { num_blocks: 256, block_size: 16, share };

    let (t, d) = SimLm::pair(11, 0.8, VOCAB);
    let (dense_streams, _, _) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);

    let (t, d) = SimLm::pair_paged(11, 0.8, VOCAB, paged(false));
    let (off_streams, off_stats, off_snap) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);

    let (t, d) = SimLm::pair_paged(11, 0.8, VOCAB, paged(true));
    let tpool = t.kv_pool().unwrap().clone();
    let (on_streams, on_stats, on_snap) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);

    assert_eq!(dense_streams, off_streams, "paged allocation must be invisible");
    assert_eq!(dense_streams, on_streams, "prefix sharing must be invisible");

    // sharing actually happened, and is visible in every telemetry layer
    assert!(tpool.stats().hit_tokens > 0);
    assert!(on_snap.kv_hit_rate > 0.0);
    assert!(on_snap.kv_blocks_total == 256);
    assert!(on_stats.iter().any(|s| s.kv_hit_tokens > 0));
    assert!(on_stats.iter().all(|s| s.kv_pool.is_some()), "done stats carry pool telemetry");
    assert_eq!(off_snap.kv_hit_rate, 0.0);
    assert!(off_stats.iter().all(|s| s.kv_hit_tokens == 0));
}

/// Property: suspend → (forced) evict → resume round-trips a session
/// losslessly: the resumed stepper re-prefills what was evicted and
/// finishes with exactly the tokens of an uninterrupted run.
#[test]
fn suspend_evict_resume_is_lossless() {
    let kv = KvConfig { num_blocks: 64, block_size: 8, share: true };
    let prompt: Vec<u32> = (0..20u32).map(|t| (t * 3 + 2) % VOCAB as u32).collect();
    let max_new = 24;
    let cfg: DecoderConfig = "rsd-s:3x3".parse().unwrap();
    let sampling = SamplingConfig::new(0.6, 1.0);

    let reference = {
        let (target, draft) = SimLm::pair_paged(5, 0.8, VOCAB, kv);
        let (strategy, rule) = build_parts(&cfg);
        let mut rng = Rng::seed_from_u64(9);
        let mut st = SpecStepper::new(
            &target, &draft, strategy, rule, sampling.clone(), &prompt, max_new,
        )
        .unwrap();
        while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {}
        st.out.clone()
    };

    let (target, draft) = SimLm::pair_paged(5, 0.8, VOCAB, kv);
    let tpool = target.kv_pool().unwrap().clone();
    let dpool = draft.kv_pool().unwrap().clone();
    target.cache_prefix(&prompt); // give resume something to re-acquire
    let (strategy, rule) = build_parts(&cfg);
    let mut rng = Rng::seed_from_u64(9);
    let mut st =
        SpecStepper::new(&target, &draft, strategy, rule, sampling, &prompt, max_new)
            .unwrap();
    for _ in 0..3 {
        assert_eq!(st.step(&target, &draft, &mut rng).unwrap(), StepOutcome::Progress);
    }
    st.suspend(&target, &draft).unwrap();
    // all session blocks are back; cached prefixes can be fully evicted
    assert_eq!(tpool.status().blocks_in_use(), 0);
    assert!(tpool.evict_all() > 0, "published prompt prefix was evictable");
    dpool.evict_all();
    st.resume(&target, &draft).unwrap();
    while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {}

    assert_eq!(st.out, reference, "preempted stream must be bit-identical");
    assert_eq!(st.stats.preemptions, 1);
    assert_eq!(st.stats.generated, max_new);
}

/// A resumed session re-acquires what is still cached: suspend, do NOT
/// evict, resume — the re-prefill shrinks to the uncached tail and the
/// hit counter records it.
#[test]
fn resume_reacquires_cached_prefix() {
    let kv = KvConfig { num_blocks: 64, block_size: 8, share: true };
    let prompt: Vec<u32> = (0..24u32).collect();
    let (target, draft) = SimLm::pair_paged(2, 0.9, VOCAB, kv);
    target.cache_prefix(&prompt);
    draft.cache_prefix(&prompt);
    let (strategy, rule) = build_parts(&"sd:3".parse().unwrap());
    let mut rng = Rng::seed_from_u64(1);
    let mut st = SpecStepper::new(
        &target,
        &draft,
        strategy,
        rule,
        SamplingConfig::new(0.5, 1.0),
        &prompt,
        16,
    )
    .unwrap();
    // both pools hold 3 full blocks of the prompt; the match is capped
    // at len-1 = 23 (one tail token always stays evaluable), the last
    // block matching partially (7 of 8 slots) — shared without copy
    assert_eq!(st.stats.kv_hit_tokens, 46);
    assert_eq!(st.step(&target, &draft, &mut rng).unwrap(), StepOutcome::Progress);
    let before = st.stats.kv_hit_tokens;
    st.suspend(&target, &draft).unwrap();
    st.resume(&target, &draft).unwrap();
    assert!(
        st.stats.kv_hit_tokens >= before + 48,
        "resume must re-acquire the cached prompt blocks (hits {} -> {})",
        before,
        st.stats.kv_hit_tokens
    );
    while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {}
    assert_eq!(st.out.len(), 16);
}

/// Acceptance criterion: an engine over a deliberately undersized pool
/// preempts (suspend + requeue-at-front) under memory pressure and
/// later completes ALL requests — no rejections, no deadlock — with
/// token streams bit-identical to a generously sized pool.
#[test]
fn undersized_pool_preempts_and_completes_all() {
    let n = 6u64;
    let max_new = 40;
    // short prompts so ADMISSION lets everyone in, then long generation
    // grows every session's committed prefix: the pressure appears
    // mid-decode (the case admission control alone cannot prevent) and
    // must be resolved by preemption. Footprint per request: ~10 prompt
    // + 40 generated + tree transients ≈ 7 blocks of 8; 20 blocks fit
    // ~2 such sessions, 6 requests need ~42.
    let small = KvConfig { num_blocks: 20, block_size: 8, share: true };
    let big = KvConfig { num_blocks: 512, block_size: 8, share: true };

    let (t, d) = SimLm::pair_paged(3, 0.8, VOCAB, big);
    let (big_streams, _, big_snap) =
        run_engine(t, d, engine_cfg(6, max_new), n, max_new, short_prompt, mixed_decoder);
    assert_eq!(big_snap.preemptions, 0, "big pool must not preempt");

    let (t, d) = SimLm::pair_paged(3, 0.8, VOCAB, small);
    let (small_streams, small_stats, snap) =
        run_engine(t, d, engine_cfg(6, max_new), n, max_new, short_prompt, mixed_decoder);

    assert_eq!(snap.completed, n);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.rejected, 0);
    assert!(snap.preemptions > 0, "undersized pool must preempt");
    assert_eq!(snap.preemptions, snap.resumes, "every victim resumed");
    assert!(small_stats.iter().any(|s| s.preemptions > 0));
    assert_eq!(
        small_streams, big_streams,
        "preemption must be token-for-token invisible"
    );
    for (i, s) in small_streams.iter().enumerate() {
        assert_eq!(s.len(), max_new, "request {i} truncated");
    }
}

/// Satellite: a prompt that can never fit the pool is answered with a
/// clean error event at admission, not a mid-decode failure.
#[test]
fn oversized_prompt_gets_clean_error() {
    let kv = KvConfig { num_blocks: 8, block_size: 8, share: true }; // 64 slots
    let (target, draft) = SimLm::pair_paged(1, 0.8, VOCAB, kv);
    let engine = Engine::new(target, draft, engine_cfg(2, 8));
    let (tx, handle) = spawn(engine);
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request {
        id: 1,
        prompt: (0..100u32).collect(),
        max_new: 8,
        decoder: None,
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp: rtx,
    })
    .unwrap();
    // a well-sized request on the same engine still succeeds
    let (rtx2, rrx2) = mpsc::channel();
    tx.send(Request {
        id: 2,
        prompt: vec![1, 2, 3],
        max_new: 8,
        decoder: None,
        sampling: None,
        priority: 0,
        deadline_ms: None,
        resp: rtx2,
    })
    .unwrap();
    drop(tx);
    match rrx.recv().unwrap() {
        Event::Error(e) => {
            assert!(e.to_string().contains("prompt too long"), "unexpected error: {e}");
            assert!(!e.retryable, "an oversized request must be terminal: {e}");
        }
        other => panic!("expected a clean error, got {other:?}"),
    }
    let mut done = false;
    while let Ok(ev) = rrx2.recv() {
        match ev {
            Event::Done(r) => {
                assert_eq!(r.stats.generated, 8);
                done = true;
                break;
            }
            Event::Error(e) => panic!("{e}"),
            Event::Tokens(_) => {}
        }
    }
    assert!(done);
    let snap = handle.join().unwrap().snapshot();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.completed, 1);
}

/// Fresh per-test cold-tier root under the OS temp dir; removed up
/// front so reruns never see a previous run's spills.
fn cold_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rsd-kvtest-{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Tentpole, stepper level: blocks evicted from the radix index spill
/// to the cold store, and the next session over the same prompt revives
/// them instead of re-prefilling — with a token stream bit-identical to
/// a run that never lost its cache.
#[test]
fn cold_tier_revives_evicted_prefix() {
    let kv = KvConfig { num_blocks: 64, block_size: 8, share: true };
    let prompt: Vec<u32> = (0..24u32).map(|t| (t * 3 + 2) % VOCAB as u32).collect();
    let max_new = 16;
    let cfg: DecoderConfig = "rsd-s:3x3".parse().unwrap();
    let sampling = SamplingConfig::new(0.6, 1.0);

    let reference = {
        let (target, draft) = SimLm::pair_paged(5, 0.8, VOCAB, kv);
        let (strategy, rule) = build_parts(&cfg);
        let mut rng = Rng::seed_from_u64(9);
        let mut st = SpecStepper::new(
            &target, &draft, strategy, rule, sampling.clone(), &prompt, max_new,
        )
        .unwrap();
        while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {}
        st.out.clone()
    };

    let dir = cold_dir("revive");
    let (target, draft) = SimLm::pair_paged_cold(5, 0.8, VOCAB, kv, &dir, 256).unwrap();
    let tpool = target.kv_pool().unwrap().clone();
    target.cache_prefix(&prompt);
    draft.cache_prefix(&prompt);
    assert!(tpool.evict_all() > 0, "published prefix was evictable");
    draft.kv_pool().unwrap().evict_all();
    assert!(tpool.stats().cold_spills > 0, "eviction must spill to the cold tier");
    // the cold index answers prefix probes without touching disk
    assert!(target.cached_prefix_len(&prompt) >= 16, "peek sees the spilled chain");

    let (strategy, rule) = build_parts(&cfg);
    let mut rng = Rng::seed_from_u64(9);
    let mut st =
        SpecStepper::new(&target, &draft, strategy, rule, sampling, &prompt, max_new)
            .unwrap();
    while st.step(&target, &draft, &mut rng).unwrap() == StepOutcome::Progress {}

    assert_eq!(st.out, reference, "cold revival must be token-invisible");
    let s = tpool.stats();
    // prefix match is capped at len-1 = 23, so exactly the first two of
    // the three spilled blocks (16 tokens) are revivable
    assert!(s.cold_hits >= 2, "revival went through the cold tier: {s:?}");
    assert!(s.cold_hit_tokens >= 16, "revived blocks saved prefill: {s:?}");
    assert_eq!(s.cold_corrupt, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole, engine level: a clean shutdown persists the radix snapshot
/// and a RESTARTED engine (fresh pools, same cold_dir) serves the
/// shared system prompt from the snapshot — bit-identical streams with
/// cold hits instead of re-prefill.
#[test]
fn engine_restart_serves_prefix_from_cold_snapshot() {
    let dir = cold_dir("restart");
    let kv = KvConfig { num_blocks: 256, block_size: 8, share: true };
    let n = 4u64;
    let max_new = 12;

    let (t, d) = SimLm::pair_paged_cold(11, 0.8, VOCAB, kv, &dir, 256).unwrap();
    let (streams1, _, snap1) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);
    assert_eq!(snap1.completed, n);
    assert!(snap1.kv_cold_spills > 0, "shutdown persists the radix to cold");

    // "restart": brand-new models and pools over the same cold_dir —
    // attach_cold replays the persisted snapshot before any request
    let (t, d) = SimLm::pair_paged_cold(11, 0.8, VOCAB, kv, &dir, 256).unwrap();
    let revived = t.kv_pool().unwrap().stats();
    assert!(revived.cold_hits > 0, "snapshot load revives blocks: {revived:?}");
    assert!(
        t.cached_prefix_len(&prompt_for(0)) >= 40,
        "system prompt is hot before the first request"
    );
    let (streams2, stats2, snap2) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);

    assert_eq!(streams2, streams1, "restart must be token-for-token invisible");
    assert!(snap2.kv_cold_hits > 0);
    assert!(snap2.kv_cold_hit_rate > 0.0);
    assert!(stats2.iter().all(|s| s.generated == max_new));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole, failure path: corrupting EVERY spilled block between runs
/// (bit flips on the target store, truncation on the draft store) must
/// degrade to re-prefill — same streams, all requests complete, the
/// damage only visible as `kv_cold_corrupt` telemetry.
#[test]
fn corrupt_cold_blocks_degrade_to_reprefill() {
    let dir = cold_dir("corrupt");
    let kv = KvConfig { num_blocks: 256, block_size: 8, share: true };
    let n = 4u64;
    let max_new = 12;

    let (t, d) = SimLm::pair_paged_cold(11, 0.8, VOCAB, kv, &dir, 256).unwrap();
    let (streams1, _, snap1) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);
    assert!(snap1.kv_cold_spills > 0);

    let hit = damage_spill_files(&dir.join("target"), 1, usize::MAX, SpillDamage::CorruptByte);
    assert!(!hit.is_empty(), "target store had spill files to damage");
    let hit = damage_spill_files(&dir.join("draft"), 2, usize::MAX, SpillDamage::Truncate);
    assert!(!hit.is_empty(), "draft store had spill files to damage");

    let (t, d) = SimLm::pair_paged_cold(11, 0.8, VOCAB, kv, &dir, 256).unwrap();
    let after_load = t.kv_pool().unwrap().stats();
    assert_eq!(after_load.cold_hits, 0, "nothing corrupt may revive: {after_load:?}");
    assert!(after_load.cold_corrupt > 0, "corruption was detected: {after_load:?}");
    let (streams2, _, snap2) =
        run_engine(t, d, engine_cfg(4, max_new), n, max_new, prompt_for, mixed_decoder);

    assert_eq!(streams2, streams1, "corruption must never change tokens");
    assert_eq!(snap2.completed, n);
    assert_eq!(snap2.failed, 0);
    assert!(snap2.kv_cold_corrupt > 0, "degradation is counted, not hidden");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Dense substrates are untouched by the admission guard: the dense sim
/// session is huge, so ordinary prompts sail through.
#[test]
fn dense_substrate_unaffected_by_guard() {
    let (target, draft) = SimLm::pair(4, 0.8, VOCAB);
    let (streams, stats, snap) =
        run_engine(target, draft, engine_cfg(2, 10), 3, 10, prompt_for, |_| None);
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.preemptions, 0);
    assert!(streams.iter().all(|s| s.len() == 10));
    assert!(stats.iter().all(|s| s.kv_pool.is_none() && s.kv_hit_tokens == 0));
}
