//! SATELLITE: byte-exactness properties of the partial-selection rewrite.
//!
//! The heap-based `gumbel_top_k_into` and the partial-partition
//! `nucleus_filter` must be indistinguishable from the sort-based
//! reference implementations (`rsd::sampling::reference`) — same
//! indices, same bit-exact values, same order, same RNG stream position
//! — across random vocabs, k, top_p, duplicate (tied) logits and `-inf`
//! entries. The RNG draw order is part of the sampling API: any
//! divergence would silently re-randomize every decoder in the repo.

use rsd::sampling::{
    gumbel_top_k_into, kernels, log_normalize, nucleus_filter, reference, LogProbs, SelectScratch,
    NEG_INF,
};
use rsd::util::Rng;

/// Random log-probs with deliberate ties (quantized values) and -inf
/// entries; roughly normalized (exactness of normalization irrelevant).
fn random_lp(rng: &mut Rng, vocab: usize, tie_prob: f64, inf_prob: f64) -> Vec<f64> {
    let mut lp: Vec<f64> = (0..vocab)
        .map(|_| {
            if rng.gen_f64() < inf_prob {
                NEG_INF
            } else if rng.gen_f64() < tie_prob {
                // heavy quantization forces exact duplicate values
                -((rng.gen_range(4) + 1) as f64)
            } else {
                -8.0 * rng.gen_f64()
            }
        })
        .collect();
    log_normalize(&mut lp);
    lp
}

#[test]
fn gumbel_top_k_heap_matches_reference_bytes_and_rng() {
    let mut meta = Rng::seed_from_u64(0xC0FFEE);
    let mut out = Vec::new();
    for trial in 0..300 {
        let vocab = 1 + meta.gen_range(200);
        let lp = LogProbs(random_lp(&mut meta, vocab, 0.4, 0.2));
        let k = meta.gen_range(vocab + 4); // includes 0 and k > support
        let seed = meta.next_u64();
        let mut r_heap = Rng::seed_from_u64(seed);
        let mut r_ref = Rng::seed_from_u64(seed);
        gumbel_top_k_into(&lp, k, &mut r_heap, &mut out);
        let want = reference::gumbel_top_k(&lp, k, &mut r_ref);
        assert_eq!(out.len(), want.len(), "trial {trial}: length");
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert_eq!(a.0, b.0, "trial {trial}: index at rank {i}");
            assert_eq!(
                a.1.to_bits(),
                b.1.to_bits(),
                "trial {trial}: perturbed value at rank {i}"
            );
        }
        // identical RNG stream position afterwards
        assert_eq!(
            r_heap.next_u64(),
            r_ref.next_u64(),
            "trial {trial}: RNG stream position diverged"
        );
    }
}

#[test]
fn gumbel_top_k_heap_matches_reference_with_all_ties() {
    // fully tied distribution: ordering must fall back to index order
    // identically in both implementations
    let mut lp = vec![-1.0; 64];
    log_normalize(&mut lp);
    let lp = LogProbs(lp);
    let mut out = Vec::new();
    for seed in 0..50u64 {
        let mut r1 = Rng::seed_from_u64(seed);
        let mut r2 = Rng::seed_from_u64(seed);
        gumbel_top_k_into(&lp, 8, &mut r1, &mut out);
        let want = reference::gumbel_top_k(&lp, 8, &mut r2);
        let got: Vec<(usize, u64)> = out.iter().map(|&(i, v)| (i, v.to_bits())).collect();
        let want: Vec<(usize, u64)> = want.iter().map(|&(i, v)| (i, v.to_bits())).collect();
        assert_eq!(got, want);
    }
}

#[test]
fn nucleus_partial_matches_reference_bytes() {
    let mut meta = Rng::seed_from_u64(0xBEEF);
    let mut sel = SelectScratch::default();
    for trial in 0..400 {
        let vocab = 1 + meta.gen_range(300);
        let lp = random_lp(&mut meta, vocab, 0.5, 0.15);
        // top_p spans tiny (keep ~1) through ~1.0 (keep everything)
        let top_p = match trial % 4 {
            0 => 0.01 + 0.2 * meta.gen_f64(),
            1 => 0.5 + 0.45 * meta.gen_f64(),
            2 => 0.9999,
            _ => meta.gen_f64(),
        };
        let mut a = lp.clone();
        let mut b = lp;
        nucleus_filter(&mut a, top_p, &mut sel);
        reference::nucleus_filter(&mut b, top_p);
        let got: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "trial {trial}: vocab {vocab} top_p {top_p}");
    }
}

#[test]
fn nucleus_partial_matches_reference_beyond_prefix_growth() {
    // vocabs straddling the 32/128/512 prefix-growth boundaries with a
    // top_p that forces several doubling retries
    let mut meta = Rng::seed_from_u64(0xF00D);
    let mut sel = SelectScratch::default();
    for &vocab in &[31usize, 32, 33, 127, 128, 129, 600, 2048] {
        // near-uniform: the mass cutoff lands deep in the tail
        let mut lp: Vec<f64> =
            (0..vocab).map(|_| -1.0 - 0.001 * meta.gen_f64()).collect();
        log_normalize(&mut lp);
        for top_p in [0.3, 0.9, 0.99, 0.999999] {
            let mut a = lp.clone();
            let mut b = lp.clone();
            nucleus_filter(&mut a, top_p, &mut sel);
            reference::nucleus_filter(&mut b, top_p);
            let got: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "vocab {vocab} top_p {top_p}");
        }
    }
}

/// Adversarial vocab rows for the lane/tail sweep: random spread,
/// all-equal (every comparison ties), fully `-inf`-masked, alternating
/// `-inf` mask (the batched draw must skip exactly the same entries as
/// the reference's serial loop), and sprinkled NaN (kept deterministic
/// by the `total_cmp` comparator).
fn adversarial_rows(meta: &mut Rng, len: usize) -> Vec<Vec<f64>> {
    vec![
        (0..len).map(|_| -8.0 * meta.gen_f64()).collect(),
        vec![-1.5; len],
        vec![NEG_INF; len],
        (0..len)
            .map(|i| if i % 2 == 0 { NEG_INF } else { -0.5 - i as f64 * 0.1 })
            .collect(),
        (0..len)
            .map(|i| if i % 5 == 3 { f64::NAN } else { -4.0 * meta.gen_f64() })
            .collect(),
    ]
}

/// SATELLITE (SIMD PR): Gumbel-Top-k bit-parity at every length through
/// the kernel lane/tail boundary (1 ..= 4·LANES + 3) under adversarial
/// inputs — the batched uniform-staging + slice-map transform must keep
/// the kept set, order, values AND the RNG stream position identical to
/// the reference's scalar draw-transform-offer loop.
#[test]
fn gumbel_top_k_parity_lane_tail_lengths_adversarial() {
    let sweep = 4 * kernels::LANES + 3;
    let mut meta = Rng::seed_from_u64(0x51AD);
    let mut out = Vec::new();
    for len in 1..=sweep {
        for (pi, row) in adversarial_rows(&mut meta, len).into_iter().enumerate() {
            let lp = LogProbs(row);
            for k in [0usize, 1, len / 2 + 1, len + 2] {
                let seed = meta.next_u64();
                let mut r_heap = Rng::seed_from_u64(seed);
                let mut r_ref = Rng::seed_from_u64(seed);
                gumbel_top_k_into(&lp, k, &mut r_heap, &mut out);
                let want = reference::gumbel_top_k(&lp, k, &mut r_ref);
                let got: Vec<(usize, u64)> =
                    out.iter().map(|&(i, v)| (i, v.to_bits())).collect();
                let want: Vec<(usize, u64)> =
                    want.iter().map(|&(i, v)| (i, v.to_bits())).collect();
                assert_eq!(got, want, "len {len} pattern {pi} k {k}");
                assert_eq!(
                    r_heap.next_u64(),
                    r_ref.next_u64(),
                    "len {len} pattern {pi} k {k}: RNG stream position diverged"
                );
            }
        }
    }
}

/// SATELLITE (SIMD PR): nucleus-filter bit-parity over the same
/// lane/tail length sweep and adversarial rows (the mass loop is shared
/// serial libm `exp`, so equality must hold to the bit even for NaN and
/// fully-masked rows).
#[test]
fn nucleus_parity_lane_tail_lengths_adversarial() {
    let sweep = 4 * kernels::LANES + 3;
    let mut meta = Rng::seed_from_u64(0x0DDB);
    let mut sel = SelectScratch::default();
    for len in 1..=sweep {
        for (pi, row) in adversarial_rows(&mut meta, len).into_iter().enumerate() {
            for top_p in [0.05, 0.5, 0.95, 0.9999] {
                let mut a = row.clone();
                let mut b = row.clone();
                nucleus_filter(&mut a, top_p, &mut sel);
                reference::nucleus_filter(&mut b, top_p);
                let got: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "len {len} pattern {pi} top_p {top_p}");
            }
        }
    }
}

#[test]
fn gumbel_top_k_wrapper_agrees_with_into() {
    let mut meta = Rng::seed_from_u64(3);
    let lp = LogProbs(random_lp(&mut meta, 80, 0.3, 0.1));
    let mut out = Vec::new();
    let mut r1 = Rng::seed_from_u64(99);
    let mut r2 = Rng::seed_from_u64(99);
    gumbel_top_k_into(&lp, 5, &mut r1, &mut out);
    let wrapper = rsd::sampling::gumbel_top_k(&lp, 5, &mut r2);
    assert_eq!(out, wrapper);
}
