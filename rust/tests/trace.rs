//! Flight-recorder integration tests: ring semantics under concurrency,
//! zero cost when disabled, the Chrome exporter against a real engine
//! run, and the stall watchdog's post-mortem dump.

use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn, Engine, Event, Request};
use rsd::sim::SimLm;
use rsd::trace::export::chrome_trace;
use rsd::trace::watchdog::{EngineStatus, Watchdog};
use rsd::trace::{EventKind, Journal, Tracer, PHASE_VERIFY};
use rsd::util::json::Json;

/// Four writers hammer one ring; the snapshot must hold exactly the
/// newest `capacity` events with gap-free sequence numbers, and every
/// event must be internally consistent (no field-level tearing between
/// two concurrent writers).
#[test]
fn concurrent_recorders_never_tear() {
    const THREADS: u64 = 4;
    const PER_THREAD: u32 = 1000;
    const CAP: usize = 512;
    let j = Arc::new(Journal::new(CAP));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let j = j.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..PER_THREAD {
                // b is a checksum of (id, a): a torn slot cannot satisfy it
                j.record(EventKind::Commit, t, i, (t as u32) ^ i.rotate_left(7));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(j.recorded(), THREADS * PER_THREAD as u64);
    let snap = j.snapshot();
    assert_eq!(snap.len(), CAP);
    // gap-free, strictly increasing, ending at the last seq ever issued
    assert!(snap.windows(2).all(|w| w[1].seq == w[0].seq + 1));
    assert_eq!(snap.last().unwrap().seq, THREADS * PER_THREAD as u64 - 1);
    for e in &snap {
        assert_eq!(e.kind, EventKind::Commit);
        assert!(e.id < THREADS && e.a < PER_THREAD);
        assert_eq!(e.b, (e.id as u32) ^ e.a.rotate_left(7), "torn event: {e:?}");
    }
}

/// A disabled tracer holds no journal at all — clones share nothing,
/// records are no-ops, snapshots are empty — so threading it through
/// the engine costs one branch per call site and zero memory.
#[test]
fn disabled_tracing_is_zero_cost() {
    let t = Tracer::off();
    assert!(!t.enabled() && t.journal().is_none());
    let t2 = t.clone();
    for i in 0..10_000 {
        t2.record(EventKind::Commit, i, 0, 0);
        t2.phase_advanced();
    }
    assert!(t2.snapshot().is_empty());
    assert_eq!(t2.progress(), 0);
    // the config spelling of "off"
    assert!(!Tracer::new(0).enabled());
    assert_eq!(EngineConfig::default().trace_events, 0);
}

/// Run 8 requests through a traced engine and validate the exported
/// Chrome trace end to end: parseable JSON, balanced B/E slices, and a
/// complete arrive -> admit -> commit -> done lifecycle per request.
#[test]
fn chrome_export_of_a_real_engine_run_is_valid() {
    let (target, draft) = SimLm::pair(11, 0.8, 64);
    let cfg = EngineConfig {
        max_concurrency: 3,
        max_queue: 64,
        default_max_tokens: 10,
        sampling: SamplingConfig::new(0.5, 1.0),
        decoder: DecoderConfig::RsdS { w: 3, l: 3 },
        seed: 7,
        fused: true,
        trace_events: 4096,
        ..EngineConfig::default()
    };
    let engine = Engine::new(target, draft, cfg);
    let trace = engine.trace.clone();
    assert!(trace.enabled(), "config trace_events must enable the journal");
    let (tx, handle) = spawn(engine);
    let mut receivers = Vec::new();
    for id in 0..8u64 {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id,
            prompt: vec![1 + id as u32, 2, 3],
            max_new: 10,
            decoder: None,
            sampling: None,
            priority: 0,
            deadline_ms: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    for rrx in receivers {
        while let Ok(ev) = rrx.recv() {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
    handle.join().unwrap();

    let events = trace.snapshot();
    assert!(trace.progress() > 0, "phase boundaries must bump the heartbeat");
    for id in 0..8u64 {
        for kind in [EventKind::ReqArrive, EventKind::ReqAdmit, EventKind::ReqDone] {
            assert!(
                events.iter().any(|e| e.kind == kind && e.id == id),
                "request {id}: missing {} event",
                kind.name()
            );
        }
        assert!(
            events.iter().any(|e| e.kind == EventKind::Commit && e.id == id),
            "request {id}: no commit boundary recorded"
        );
    }
    assert!(events.iter().any(|e| e.kind == EventKind::RoundBegin));

    // the exporter's output must survive a parse round-trip
    let doc = chrome_trace(&events);
    let parsed = Json::parse(&doc.to_string()).expect("chrome trace is valid JSON");
    let tev = match parsed.get("traceEvents") {
        Some(Json::Arr(a)) => a,
        other => panic!("traceEvents missing: {other:?}"),
    };
    assert!(tev.len() > events.len(), "metadata + one entry per event");
    // B/E slices balance per (tid, name) — nesting is per thread lane
    let mut depth: std::collections::HashMap<String, i64> = std::collections::HashMap::new();
    for e in tev {
        let ph = e.get("ph").and_then(Json::as_str).expect("every event has ph");
        if ph != "B" && ph != "E" {
            continue;
        }
        let tid = e.get("tid").and_then(Json::as_f64).unwrap_or(-1.0);
        let name = e.get("name").and_then(Json::as_str).unwrap_or("?");
        let d = depth.entry(format!("{tid}:{name}")).or_insert(0);
        *d += if ph == "B" { 1 } else { -1 };
        assert!(*d >= 0, "E before B for {name} on tid {tid}");
    }
    assert!(depth.values().all(|&d| d == 0), "unbalanced slices: {depth:?}");
}

/// Freeze the heartbeat with work in flight: the watchdog must write a
/// dump naming the stalled request and carrying its last phase event.
#[test]
fn watchdog_dumps_stalled_engine_state() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("rsd-watchdog-test-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let tracer = Tracer::new(256);
    // a request mid-verify, then silence: the classic hang signature
    tracer.record(EventKind::ReqAdmit, 7, 0, 1);
    tracer.record(EventKind::RoundBegin, 3, 1, 0);
    tracer.record(EventKind::PhaseBegin, 3, PHASE_VERIFY, 1);
    tracer.phase_advanced();

    let status = Arc::new(Mutex::new(EngineStatus {
        rounds: 3,
        active: vec![(7, 42)],
        queued: 1,
        parked: 0,
        pool: None,
    }));
    let wd = Watchdog::spawn(
        tracer.clone(),
        status,
        Duration::from_millis(40),
        path.clone(),
    )
    .expect("enabled tracer + nonzero stall must spawn");

    let deadline = Instant::now() + Duration::from_secs(10);
    while !path.exists() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    wd.stop();
    let dump = std::fs::read_to_string(&path).expect("watchdog never dumped");
    let _ = std::fs::remove_file(&path);

    let doc = Json::parse(&dump).expect("dump is valid JSON");
    let wdj = doc.get("watchdog").expect("watchdog section");
    assert!(wdj.usize_field("stalled_ms").unwrap() >= 40);
    let st = wdj.get("status").expect("engine status in dump");
    assert_eq!(st.usize_field("queued").unwrap(), 1);
    let active = match st.get("active") {
        Some(Json::Arr(a)) => a,
        other => panic!("active missing: {other:?}"),
    };
    assert!(
        active.iter().any(|r| r.usize_field("request").ok() == Some(7)),
        "stalled request 7 absent from dump"
    );
    // the journal in the dump ends at the stalled request's last phase
    // event (the open verify slice), plus the watchdog's own marker
    let trace = doc.get("trace").expect("trace section");
    let tev = match trace.get("traceEvents") {
        Some(Json::Arr(a)) => a,
        other => panic!("traceEvents missing: {other:?}"),
    };
    assert!(
        tev.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("B")
            && e.get("name").and_then(Json::as_str) == Some("verify")),
        "last phase event (verify begin) missing from dump"
    );
    assert!(
        tev.iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("watchdog")),
        "watchdog marker missing"
    );

    // no re-dump for the same frozen heartbeat: spawning again with the
    // file removed would dump again, but the original must not
    assert!(!path.exists());
}

/// Ring wraparound through the public engine-facing handle: only the
/// newest `capacity` events survive, oldest first.
#[test]
fn ring_wraparound_keeps_newest() {
    let t = Tracer::new(16);
    for i in 0..100u64 {
        t.record(EventKind::QueueDepth, 0, i as u32, 0);
    }
    let snap = t.snapshot();
    assert_eq!(snap.len(), 16);
    assert_eq!(snap.first().unwrap().a, 84);
    assert_eq!(snap.last().unwrap().a, 99);
}
