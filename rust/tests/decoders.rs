//! Decoder integration tests on the sim substrate: exact distribution
//! recovery for every algorithm, determinism, and the paper's qualitative
//! orderings across the full Exp1/Exp2 config grids.

use rsd::bench::{self, first_token_tv, BenchOpts};
use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::decode::generate;
use rsd::sim::SimLm;
use rsd::util::Rng;

fn all_tree_decoders() -> Vec<DecoderConfig> {
    vec![
        DecoderConfig::Sd { l: 3 },
        DecoderConfig::SpecTr { k: 2, l: 3 },
        DecoderConfig::RsdC { branches: vec![2, 2, 1] },
        DecoderConfig::RsdC { branches: vec![3, 1, 1] },
        DecoderConfig::RsdS { w: 3, l: 3 },
    ]
}

/// The accuracy column of every paper table, sharpened: each decoder's
/// first-token distribution must match the exact target distribution.
#[test]
fn every_decoder_recovers_target_distribution() {
    let (target, draft) = SimLm::pair(11, 0.5, 24); // high discrepancy
    let sampling = SamplingConfig::new(0.8, 1.0);
    for cfg in all_tree_decoders() {
        let tv = first_token_tv(&cfg, &sampling, &target, &draft, &[5, 9, 2], 30_000, 3)
            .unwrap();
        assert!(tv < 0.02, "{cfg:?}: TV {tv}");
    }
}

/// Same but with nucleus filtering active (the Dolly configuration):
/// filtering applies to both draft and target, recovery must still hold.
#[test]
fn recovery_holds_under_top_p() {
    let (target, draft) = SimLm::pair(13, 0.6, 24);
    let sampling = SamplingConfig::new(1.0, 0.9);
    for cfg in [DecoderConfig::RsdS { w: 3, l: 2 }, DecoderConfig::RsdC { branches: vec![3, 1] }]
    {
        let tv =
            first_token_tv(&cfg, &sampling, &target, &draft, &[1, 2], 30_000, 5).unwrap();
        assert!(tv < 0.02, "{cfg:?}: TV {tv}");
    }
}

#[test]
fn decoding_is_deterministic_per_seed() {
    let (target, draft) = SimLm::pair(3, 0.7, 64);
    let sampling = SamplingConfig::new(0.5, 1.0);
    for cfg in all_tree_decoders() {
        let mut r1 = Rng::seed_from_u64(42);
        let mut r2 = Rng::seed_from_u64(42);
        let a = generate(&cfg, &sampling, &target, &draft, &[7, 7, 7], 32, &mut r1).unwrap();
        let b = generate(&cfg, &sampling, &target, &draft, &[7, 7, 7], 32, &mut r2).unwrap();
        assert_eq!(a.tokens, b.tokens, "{cfg:?}");
    }
}

/// All Exp1 configurations run clean and tree decoders beat AR on block
/// efficiency for a well-aligned draft (the paper's headline ordering).
#[test]
fn exp1_grid_runs_and_trees_beat_ar() {
    let (target, draft) = SimLm::pair(0, 0.93, 96);
    let sampling = SamplingConfig::new(0.4, 1.0);
    let opts = BenchOpts { max_new: 48, reps: 3, tv_trials: 0, seed: 0 };
    let prompts = vec![vec![3u32, 5, 8], vec![2, 2, 9], vec![60, 4, 33]];
    for dl in [2usize, 3] {
        for cfg in bench::exp1_configs(dl) {
            let row =
                bench::bench_decoder(&cfg, &sampling, &target, &draft, &prompts, &opts).unwrap();
            assert!(row.eff > 1.1, "{}: eff {}", cfg.label(), row.eff);
            assert!(row.nodes_per_call as usize <= cfg.budget());
        }
    }
}

/// Exp2 invariant: the actual tree size per round never exceeds the
/// declared target budget, for every configuration in the paper's grid.
#[test]
fn exp2_budgets_respected_at_runtime() {
    let (target, draft) = SimLm::pair(5, 0.7, 96);
    let sampling = SamplingConfig::new(0.6, 1.0);
    let mut rng = Rng::seed_from_u64(2);
    for b in [6usize, 10, 14, 21, 30] {
        for cfg in bench::exp2_configs(b).into_iter().skip(1) {
            // skip SD row (budget = L by construction)
            let run =
                generate(&cfg, &sampling, &target, &draft, &[1, 2, 3], 40, &mut rng).unwrap();
            let per_round = run.stats.tree_nodes as f64 / run.stats.decode_calls as f64;
            assert!(
                per_round <= b as f64 + 1e-9,
                "{}: {per_round} nodes/round > budget {b}",
                cfg.label()
            );
        }
    }
}

/// RSD-S must dominate SpecTr on block efficiency (paper Fig. 4: strict
/// ordering for every DL) when the draft is imperfect.
#[test]
fn rsd_s_dominates_spectr() {
    let (target, draft) = SimLm::pair(21, 0.6, 64);
    let sampling = SamplingConfig::new(0.7, 1.0);
    let opts = BenchOpts { max_new: 64, reps: 6, tv_trials: 0, seed: 4 };
    let prompts = vec![vec![9u32, 1], vec![4, 4], vec![17, 60]];
    let mut wins = 0;
    let mut total = 0;
    for (k, l) in [(3usize, 3usize), (5, 4)] {
        let spectr = bench::bench_decoder(
            &DecoderConfig::SpecTr { k, l },
            &sampling,
            &target,
            &draft,
            &prompts,
            &opts,
        )
        .unwrap();
        let rsds = bench::bench_decoder(
            &DecoderConfig::RsdS { w: k, l },
            &sampling,
            &target,
            &draft,
            &prompts,
            &opts,
        )
        .unwrap();
        total += 1;
        if rsds.eff > spectr.eff {
            wins += 1;
        }
    }
    assert_eq!(wins, total, "RSD-S must beat SpecTr at equal (K, L)");
}

/// Alignment monotonicity: higher draft-target alignment (alpha) yields
/// higher block efficiency for RSD-S.
#[test]
fn efficiency_increases_with_alignment() {
    let sampling = SamplingConfig::new(0.5, 1.0);
    let opts = BenchOpts { max_new: 48, reps: 4, tv_trials: 0, seed: 6 };
    let prompts = vec![vec![1u32, 2, 3]];
    let mut last = 0.0;
    for alpha in [0.2, 0.6, 0.95] {
        let (target, draft) = SimLm::pair(30, alpha, 64);
        let row = bench::bench_decoder(
            &DecoderConfig::RsdS { w: 4, l: 3 },
            &sampling,
            &target,
            &draft,
            &prompts,
            &opts,
        )
        .unwrap();
        assert!(row.eff > last, "alpha {alpha}: eff {} <= {last}", row.eff);
        last = row.eff;
    }
}

/// Long generation with a tiny cache must stop gracefully (capacity
/// guard), never error.
#[test]
fn capacity_exhaustion_is_graceful() {
    // sim cache is huge; emulate by very long generation
    let (target, draft) = SimLm::pair(8, 0.8, 32);
    let sampling = SamplingConfig::default();
    let mut rng = Rng::seed_from_u64(0);
    let run = generate(
        &DecoderConfig::RsdS { w: 3, l: 3 },
        &sampling,
        &target,
        &draft,
        &[1],
        2000,
        &mut rng,
    )
    .unwrap();
    assert_eq!(run.tokens.len(), 2000);
}
