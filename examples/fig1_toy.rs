//! Regenerates the paper's Figure 1: acceptance rates of multi-round
//! rejection sampling, K-SEQ (tuned γ), OTM and recursive rejection
//! sampling on the Bernoulli toy (draft Ber(p), target Ber(q), K = 2),
//! plus a Monte-Carlo cross-check of the closed forms.
//!
//!     cargo run --release --example fig1_toy

use rsd::decode::rrs::{LevelOutcome, Rrs, VerifyRule};
use rsd::decode::toy;
use rsd::sampling::{gumbel_top_k, LogProbs};
use rsd::util::Rng;

fn main() {
    // the paper's figure varies the draft-target discrepancy; we sweep q
    // for two representative p values and print all four curves.
    for p in [0.25f64, 0.75] {
        println!("\nFigure 1 slice: draft = Ber({p}), K = 2");
        println!(
            "{:>5} {:>12} {:>9} {:>7} {:>7} {:>12}",
            "q", "multi-round", "K-SEQ*", "OTM", "RRS", "RRS (MC)"
        );
        for i in 0..=10 {
            let q = i as f64 / 10.0;
            let row = toy::figure1_row(p, q.clamp(0.01, 0.99));
            let mc = monte_carlo_rrs(p, q.clamp(0.01, 0.99), 40_000);
            println!(
                "{:>5.2} {:>12.3} {:>9.3} {:>7.3} {:>7.3} {:>12.3}",
                q, row.multiround, row.kseq, row.otm, row.rrs, mc
            );
        }
    }
    println!("\nShape to verify against the paper:");
    println!(" * RRS = 1.0 everywhere (binary vocab: 2 tokens w/o replacement cover X)");
    println!(" * baselines decay as |p - q| grows; OTM >= K-SEQ* >= multi-round");
}

fn monte_carlo_rrs(p: f64, q: f64, trials: usize) -> f64 {
    let plp = LogProbs(vec![(1.0 - p).ln(), p.ln()]);
    let qlp = LogProbs(vec![(1.0 - q).ln(), q.ln()]);
    let mut rng = Rng::seed_from_u64(1234);
    let mut acc = 0usize;
    for _ in 0..trials {
        let sib: Vec<u32> =
            gumbel_top_k(&plp, 2, &mut rng).iter().map(|&(i, _)| i as u32).collect();
        if matches!(Rrs.verify(&sib, &plp, &qlp, &mut rng), LevelOutcome::Accept { .. }) {
            acc += 1;
        }
    }
    acc as f64 / trials as f64
}
