//! Didactic: print the draft-token trees RSD-C and RSD-S actually build
//! (paper Figure 3) and trace one verification walk through each.
//!
//!     cargo run --release --example tree_visualize [--sim]

use rsd::config::SamplingConfig;
use rsd::decode::rrs::Rrs;
use rsd::decode::spec::{verify_tree, DraftTree, TreeNode, TreeStrategy};
use rsd::decode::strategies::{GumbelTopK, StochasticBeam};
use rsd::llm::{EvalNode, Llm};
use rsd::sampling::process_logits;
use rsd::sim::SimLm;
use rsd::tokenizer::Tokenizer;
use rsd::util::Rng;

fn main() -> anyhow::Result<()> {
    let tok = Tokenizer::new();
    let (target, draft) = SimLm::pair(4, 0.75, 32);
    let prompt = tok.encode("speculative ");
    let sampling = SamplingConfig::new(0.8, 1.0);
    let mut rng = Rng::seed_from_u64(3);

    println!("=== RSD-C, b = (3, 2, 1)  (paper Fig. 3a) ===");
    let mut strat = GumbelTopK::new(vec![3, 2, 1]);
    build_and_show(&target, &draft, &mut strat, &sampling, &prompt, &tok, &mut rng)?;

    println!("\n=== RSD-S, W = 3, L = 3  (paper Fig. 3b) ===");
    let mut strat = StochasticBeam::new(3, 3);
    build_and_show(&target, &draft, &mut strat, &sampling, &prompt, &tok, &mut rng)?;
    Ok(())
}

fn build_and_show<S: TreeStrategy>(
    target: &SimLm,
    draft: &SimLm,
    strategy: &mut S,
    sampling: &SamplingConfig,
    prompt: &[u32],
    tok: &Tokenizer,
    rng: &mut Rng,
) -> anyhow::Result<()> {
    // --- draft phase (mirrors SpecStepper::step, instrumented) ----------
    let mut dsess = draft.begin()?;
    let nodes: Vec<EvalNode> = prompt
        .iter()
        .enumerate()
        .map(|(i, &t)| if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) })
        .collect();
    let drows = draft.eval(&mut dsess, &nodes)?;
    let root_lp = process_logits(drows.last().unwrap(), sampling.temperature, sampling.top_p);
    let mut tree = DraftTree { nodes: Vec::new(), levels: Vec::new(), root_draft_lp: root_lp };
    strategy.begin_round();
    let mut pending = prompt.len();
    for level in 0..strategy.depth() {
        let mut children = Vec::new();
        strategy.expand(&tree, level, rng, &mut children);
        if children.is_empty() {
            break;
        }
        let mut created = Vec::new();
        for c in &children {
            let id = tree.nodes.len();
            tree.nodes.push(TreeNode {
                token: c.token,
                parent: c.parent,
                level,
                mult: 1,
                draft_pending: None,
                draft_lp: None,
            });
            created.push(id);
        }
        tree.levels.push(created.clone());
        strategy.on_created(&tree, level, &created);
        if level + 1 < strategy.depth() {
            let nodes: Vec<EvalNode> = created
                .iter()
                .map(|&id| {
                    let p = match tree.nodes[id].parent {
                        None => prompt.len() as i64 - 1,
                        Some(pp) => tree.nodes[pp].draft_pending.unwrap() as i64,
                    };
                    EvalNode { token: tree.nodes[id].token, parent: p }
                })
                .collect();
            let rows = draft.eval(&mut dsess, &nodes)?;
            for (i, &id) in created.iter().enumerate() {
                tree.nodes[id].draft_pending = Some(pending + i);
                tree.nodes[id].draft_lp =
                    Some(process_logits(&rows[i], sampling.temperature, sampling.top_p));
            }
            pending += created.len();
        }
    }

    // print the tree
    fn show(tree: &DraftTree, tok: &Tokenizer, parent: Option<usize>, indent: usize) {
        for level in tree.levels.iter() {
            for &id in level {
                if tree.nodes[id].parent == parent {
                    let ch = tok.decode(&[tree.nodes[id].token]);
                    println!("{:indent$}└─ [{id}] {ch:?}", "", indent = indent);
                    show(tree, tok, Some(id), indent + 3);
                }
            }
        }
    }
    println!("(root context: {:?})", tok.decode(prompt));
    show(&tree, tok, None, 0);

    // --- target phase + verification -------------------------------------
    let mut tsess = target.begin()?;
    let mut tnodes: Vec<EvalNode> = prompt
        .iter()
        .enumerate()
        .map(|(i, &t)| if i == 0 { EvalNode::root(t) } else { EvalNode::child(t, i - 1) })
        .collect();
    for n in &tree.nodes {
        let parent = match n.parent {
            None => (prompt.len() - 1) as i64,
            Some(p) => (prompt.len() + p) as i64,
        };
        tnodes.push(EvalNode { token: n.token, parent });
    }
    let trows = target.eval(&mut tsess, &tnodes)?;
    let root_q =
        process_logits(&trows[prompt.len() - 1], sampling.temperature, sampling.top_p);
    let node_q: Vec<_> = trows[prompt.len()..]
        .iter()
        .map(|r| process_logits(r, sampling.temperature, sampling.top_p))
        .collect();
    let vr = verify_tree(&tree, &Rrs, &root_q, &node_q, rng);
    let path: Vec<String> = vr
        .accepted
        .iter()
        .map(|&id| format!("[{id}] {:?}", tok.decode(&[tree.nodes[id].token])))
        .collect();
    println!(
        "verification: accepted path {{ {} }} + final {:?} ({})",
        path.join(" -> "),
        tok.decode(&[vr.final_token]),
        if vr.bonus { "bonus from q" } else { "residual resample" },
    );
    Ok(())
}
