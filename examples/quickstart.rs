//! Quickstart: load the AOT artifacts, decode one prompt with every
//! algorithm, and print the paper's headline comparison.
//!
//!     make artifacts && cargo run --release --example quickstart

use rsd::config::{DecoderConfig, SamplingConfig};
use rsd::decode::generate;
use rsd::llm::Llm;
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::tokenizer::Tokenizer;
use rsd::util::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let (target, draft) = PjrtLm::load_pair(&rt, "artifacts")?;
    println!(
        "target: {} params | draft: {} params (ratio {:.1}x)\n",
        target.param_count(),
        draft.param_count(),
        target.param_count() as f64 / draft.param_count() as f64
    );

    let tok = Tokenizer::new();
    let prompt = tok.encode("the sound of the ");
    let sampling = SamplingConfig::new(0.3, 1.0);

    let decoders = [
        DecoderConfig::Ar,
        DecoderConfig::Sd { l: 3 },
        DecoderConfig::SpecTr { k: 3, l: 3 },
        DecoderConfig::RsdC { branches: vec![2, 2, 2] },
        DecoderConfig::RsdS { w: 4, l: 3 },
    ];

    println!(
        "{:<16} {:>6} {:>6} {:>9} {:>7}  sample",
        "decoder", "eff", "MBSU", "tok/s", "rounds"
    );
    for cfg in decoders {
        let mut rng = Rng::seed_from_u64(0);
        let run = generate(&cfg, &sampling, &target, &draft, &prompt, 64, &mut rng)?;
        let s = &run.stats;
        let text: String = tok.decode(&run.tokens).chars().take(28).collect();
        println!(
            "{:<16} {:>6.3} {:>6.3} {:>9.1} {:>7}  {:?}",
            cfg.label(),
            s.block_efficiency(),
            s.mbsu(cfg.depth(), draft.param_count(), target.param_count()),
            s.token_rate(),
            s.decode_calls,
            text,
        );
    }
    println!("\nRSD-S should top both efficiency columns (paper Fig. 4).");
    Ok(())
}
