//! End-to-end serving driver (deliverable (e) of DESIGN.md): load the
//! trained model pair, run the full coordinator (admission -> continuous
//! batching -> speculative rounds -> streaming), push an open-loop
//! Poisson workload of real corpus prompts through it, and report
//! latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example serve_batch

use std::sync::mpsc;
use std::time::{Duration, Instant};

use rsd::bench::workload;
use rsd::config::{DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn_with, Event, Request};
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;

const N_REQUESTS: usize = 24;
const MAX_NEW: usize = 32;
const RATE: f64 = 4.0; // requests/second (open loop)

fn main() -> anyhow::Result<()> {
    for decoder in [DecoderConfig::Ar, DecoderConfig::RsdS { w: 3, l: 3 }] {
        run_one(decoder)?;
    }
    Ok(())
}

fn run_one(decoder: DecoderConfig) -> anyhow::Result<()> {
    let cfg = EngineConfig {
        max_concurrency: 4,
        max_queue: 64,
        default_max_tokens: MAX_NEW,
        sampling: SamplingConfig { temperature: 0.3, top_p: 1.0 },
        decoder: decoder.clone(),
        seed: 0,
    };
    let (tx, handle) = spawn_with(move || {
        let rt = Runtime::cpu()?;
        let (target, draft) = PjrtLm::load_pair(&rt, "artifacts")?;
        Ok(rsd::coordinator::engine::Engine::new(target, draft, cfg))
    });

    let prompts = workload::corpus_prompts("artifacts", N_REQUESTS, 32, 7)?;
    let arrivals = workload::poisson_arrivals(N_REQUESTS, RATE, 11);

    println!("\n=== serve_batch: decoder {} ===", decoder.label());
    println!("{N_REQUESTS} requests, Poisson {RATE}/s, {MAX_NEW} tokens each");

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for (i, (prompt, at)) in prompts.into_iter().zip(arrivals).enumerate() {
        // open-loop arrivals
        let now = t0.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i as u64,
            prompt,
            max_new: MAX_NEW,
            decoder: None,
            sampling: None,
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut total_tokens = 0usize;
    let mut effs = Vec::new();
    for (i, rrx) in receivers.into_iter().enumerate() {
        loop {
            match rrx.recv() {
                Ok(Event::Tokens(t)) => total_tokens += t.len(),
                Ok(Event::Done(stats)) => {
                    effs.push(stats.block_efficiency());
                    break;
                }
                Ok(Event::Error(e)) => {
                    println!("request {i}: ERROR {e}");
                    break;
                }
                Err(_) => break,
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = handle.join().unwrap()?;
    let snap = metrics.snapshot();
    let mean_eff = effs.iter().sum::<f64>() / effs.len().max(1) as f64;

    println!("completed {} / rejected {}", snap.completed, snap.rejected);
    println!(
        "throughput {:.1} tok/s  |  mean block efficiency {:.3}",
        total_tokens as f64 / wall,
        mean_eff
    );
    println!(
        "latency p50/p95/p99: {:.2}/{:.2}/{:.2} s  |  TTFT p50/p95: {:.2}/{:.2} s",
        snap.latency_p50, snap.latency_p95, snap.latency_p99, snap.ttft_p50, snap.ttft_p95
    );
    println!(
        "decode rounds {}  |  draft calls {}  |  tokens out {}",
        snap.decode_rounds, snap.draft_calls, snap.tokens_out
    );
    Ok(())
}
