//! End-to-end serving driver: run the full coordinator (admission ->
//! continuous batching -> speculative rounds -> streaming), push an
//! open-loop Poisson workload through it, and report latency/throughput.
//!
//! Scenarios: the AR baseline, a static RSD-S tree, a fleet-wide
//! adaptive decoder (`adaptive:30`), and a *heterogeneous* mix where
//! alternating requests carry `adaptive:6` / `adaptive:30` overrides —
//! exercising the engine's budget-weighted admission
//! (`EngineConfig::max_active_budget`).
//!
//! Runs against the AOT/PJRT model pair when `artifacts/` exists, and
//! falls back to the analytic sim substrate otherwise, so the example
//! works on any machine:
//!
//!     cargo run --release --example serve_batch

use std::sync::mpsc;
use std::time::{Duration, Instant};

use rsd::bench::workload;
use rsd::config::{AdaptiveFamily, DecoderConfig, EngineConfig, SamplingConfig};
use rsd::coordinator::engine::{spawn_with, Engine, Event, Request};
use rsd::model::PjrtLm;
use rsd::runtime::Runtime;
use rsd::sim::SimLm;

const N_REQUESTS: usize = 24;
const MAX_NEW: usize = 32;
const RATE: f64 = 4.0; // requests/second (open loop)

fn main() -> anyhow::Result<()> {
    // sim fallback: no artifacts, or a build without the PJRT runtime
    let use_sim = cfg!(not(pjrt_runtime))
        || !std::path::Path::new("artifacts/manifest.json").exists();
    if use_sim {
        eprintln!("no artifacts / PJRT runtime — driving the engine on the SimLm substrate");
    }
    for decoder in [
        DecoderConfig::Ar,
        DecoderConfig::RsdS { w: 3, l: 3 },
        DecoderConfig::Adaptive { budget: 30, family: AdaptiveFamily::Auto },
    ] {
        run_one(decoder, None, use_sim)?;
    }
    // heterogeneous per-request budgets: latency-sensitive requests get
    // adaptive:6, throughput-hungry ones adaptive:30; the weighted
    // admission cap keeps the wide trees from crowding out the narrow
    let overrides: Vec<Option<DecoderConfig>> = (0..N_REQUESTS)
        .map(|i| {
            let budget = if i % 2 == 0 { 6 } else { 30 };
            Some(DecoderConfig::Adaptive { budget, family: AdaptiveFamily::Auto })
        })
        .collect();
    run_one(DecoderConfig::RsdS { w: 3, l: 3 }, Some(overrides), use_sim)?;
    Ok(())
}

fn run_one(
    decoder: DecoderConfig,
    overrides: Option<Vec<Option<DecoderConfig>>>,
    use_sim: bool,
) -> anyhow::Result<()> {
    let cfg = EngineConfig {
        max_concurrency: 4,
        max_queue: 64,
        default_max_tokens: MAX_NEW,
        max_active_budget: 72, // two wide trees + change, never four
        sampling: SamplingConfig::new(0.3, 1.0),
        decoder: decoder.clone(),
        seed: 0,
        fused: true,
        ..EngineConfig::default()
    };
    // keep a handle on the speculation analytics so the ledger can be
    // read back after the engine drains (the engine records into the
    // same handle it is handed)
    let analytics = rsd::obs::Analytics::from_config(&cfg);
    let (tx, handle) = if use_sim {
        let cfg = cfg.clone();
        let a = analytics.clone();
        spawn_with(move || {
            let (target, draft) = SimLm::pair(0, 0.8, 256);
            Ok(Engine::new(target, draft, cfg).with_analytics(a))
        })
    } else {
        let a = analytics.clone();
        spawn_with(move || {
            let rt = Runtime::cpu()?;
            let (target, draft) = PjrtLm::load_pair(&rt, "artifacts")?;
            Ok(Engine::new(target, draft, cfg).with_analytics(a))
        })
    };

    let prompts = if use_sim {
        workload::random_prompts(N_REQUESTS, 32, 256, 7)
    } else {
        workload::corpus_prompts("artifacts", N_REQUESTS, 32, 7)?
    };
    let arrivals = workload::poisson_arrivals(N_REQUESTS, RATE, 11);

    let title = match &overrides {
        Some(_) => "heterogeneous adaptive:6 / adaptive:30".to_string(),
        None => format!("decoder {}", decoder.label()),
    };
    println!("\n=== serve_batch: {title} ===");
    println!("{N_REQUESTS} requests, Poisson {RATE}/s, {MAX_NEW} tokens each");

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    for (i, (prompt, at)) in prompts.into_iter().zip(arrivals).enumerate() {
        // open-loop arrivals
        let now = t0.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: i as u64,
            prompt,
            max_new: MAX_NEW,
            decoder: overrides.as_ref().and_then(|o| o[i].clone()),
            sampling: None,
            // alternate scheduling classes: odd requests are
            // latency-sensitive and jump the queue under load
            priority: if i % 2 == 0 { 0 } else { 1 },
            deadline_ms: if i % 2 == 0 { None } else { Some(500) },
            resp: rtx,
        })
        .unwrap();
        receivers.push(rrx);
    }
    drop(tx);

    let mut total_tokens = 0usize;
    let mut effs = Vec::new();
    for (i, rrx) in receivers.into_iter().enumerate() {
        loop {
            match rrx.recv() {
                Ok(Event::Tokens(t)) => total_tokens += t.len(),
                Ok(Event::Done(report)) => {
                    effs.push(report.stats.block_efficiency());
                    break;
                }
                Ok(Event::Error(e)) => {
                    println!("request {i}: ERROR {e}");
                    break;
                }
                Err(_) => break,
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = handle.join().unwrap()?;
    let snap = metrics.snapshot();
    let mean_eff = effs.iter().sum::<f64>() / effs.len().max(1) as f64;

    println!("completed {} / rejected {}", snap.completed, snap.rejected);
    println!(
        "throughput {:.1} tok/s  |  mean block efficiency {:.3}",
        total_tokens as f64 / wall,
        mean_eff
    );
    println!(
        "latency p50/p95/p99: {:.2}/{:.2}/{:.2} s  |  TTFT p50/p95: {:.2}/{:.2} s",
        snap.latency_p50, snap.latency_p95, snap.latency_p99, snap.ttft_p50, snap.ttft_p95
    );
    println!(
        "queue wait p50/p95: {:.3}/{:.3} s  |  mid-round admissions: {}",
        snap.queue_wait_p50, snap.queue_wait_p95, snap.mid_round_admitted
    );
    println!(
        "decode rounds {}  |  draft calls {}  |  tokens out {}",
        snap.decode_rounds, snap.draft_calls, snap.tokens_out
    );
    if !snap.accept_rate_by_level.is_empty() {
        let rates: Vec<String> =
            snap.accept_rate_by_level.iter().map(|r| format!("{r:.2}")).collect();
        println!("acceptance by level: [{}]", rates.join(", "));
        let hist: Vec<String> = snap
            .round_nodes_hist
            .iter()
            .map(|(nodes, count)| format!("{nodes}:{count}"))
            .collect();
        println!("nodes-per-round histogram: {{{}}}", hist.join(", "));
    }
    // the speculation ledger: compute-budget accounting for the whole
    // scenario — accepted tokens per target forward is the paper's
    // fixed-budget headline metric
    let totals = analytics.totals();
    if totals.target_forwards > 0 {
        println!(
            "target forwards {}  |  tree nodes {}  |  accepted/forward {:.3}  |  tokens/forward {:.3}",
            totals.target_forwards,
            totals.tree_nodes,
            totals.accepted_per_target_forward(),
            totals.tokens_per_target_forward()
        );
        let used = totals.level_attempts.iter().rposition(|&a| a > 0).map_or(0, |p| p + 1);
        if used > 0 {
            let curve = totals.acceptance_by_level();
            let rates: Vec<String> =
                curve[..used].iter().map(|r| format!("{r:.2}")).collect();
            println!("ledger acceptance curve (by tree level): [{}]", rates.join(", "));
        }
    }
    Ok(())
}
