#!/usr/bin/env python3
"""Warn-only trend diff between two rsd-bench-v1 snapshots.

Joins entries on (section, name) and prints ns_per_op changes, flagging
regressions beyond a threshold (default 10%). Also diffs the top-level
per-kernel nanoseconds map (`kernels.*.ns_per_op`) when both snapshots
carry one.

Always exits 0: this is a trend signal for humans reading CI logs, not a
gate — the hard perf gates (speedup floors, 0-alloc) live inside the
bench binary itself. Stdlib only.

Usage:
    python3 bench_diff.py OLD.json NEW.json [--threshold 0.10]
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        snap = json.load(fh)
    if snap.get("schema") not in (None, "rsd-bench-v1"):
        print(f"note: {path} has unexpected schema {snap.get('schema')!r}")
    return snap


def entry_map(snap: dict) -> dict[tuple[str, str], float]:
    out: dict[tuple[str, str], float] = {}
    for e in snap.get("entries", []):
        ns = e.get("ns_per_op")
        if isinstance(ns, (int, float)) and ns > 0:
            out[(e.get("section", ""), e.get("name", ""))] = float(ns)
    return out


def kernel_map(snap: dict) -> dict[tuple[str, str], float]:
    out: dict[tuple[str, str], float] = {}
    for name, rec in (snap.get("kernels") or {}).items():
        ns = rec.get("ns_per_op") if isinstance(rec, dict) else None
        if isinstance(ns, (int, float)) and ns > 0:
            out[("kernels", name)] = float(ns)
    return out


def diff(old: dict[tuple[str, str], float], new: dict[tuple[str, str], float],
         threshold: float) -> int:
    regressions = 0
    for key in sorted(set(old) & set(new)):
        section, name = key
        o, n = old[key], new[key]
        ratio = n / o - 1.0
        if ratio > threshold:
            regressions += 1
            flag = "  <-- REGRESSION"
        elif ratio < -threshold:
            flag = "  (improved)"
        else:
            continue
        print(f"  [{section}] {name}: {o:.1f} -> {n:.1f} ns/op ({ratio:+.1%}){flag}")
    only_new = sorted(set(new) - set(old))
    if only_new:
        print(f"  {len(only_new)} entr{'y' if len(only_new) == 1 else 'ies'} "
              "new in this run (no previous baseline)")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative ns_per_op increase flagged as a regression")
    args = ap.parse_args()
    try:
        old_snap, new_snap = load(args.old), load(args.new)
    except (OSError, json.JSONDecodeError) as exc:
        # missing/corrupt previous snapshot is normal on first runs
        print(f"bench_diff: skipping ({exc})")
        return 0

    print(f"bench trend: {args.old} -> {args.new} "
          f"(threshold {args.threshold:.0%}, warn-only)")
    total = diff(entry_map(old_snap), entry_map(new_snap), args.threshold)
    total += diff(kernel_map(old_snap), kernel_map(new_snap), args.threshold)
    if total:
        print(f"bench_diff: {total} entr{'y' if total == 1 else 'ies'} "
              f"regressed >{args.threshold:.0%} (warn-only, not failing the build)")
    else:
        print("bench_diff: no regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
