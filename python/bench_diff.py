#!/usr/bin/env python3
"""Trend diff between two rsd-bench-v1 snapshots.

Joins entries on (section, name) and prints ns_per_op changes, flagging
regressions beyond a threshold (default 10%). Also diffs the top-level
per-kernel nanoseconds map (`kernels.*.ns_per_op`) when both snapshots
carry one.

Two modes:

* default (warn-only): always exits 0 — a trend signal for humans
  reading CI logs, not a gate. The hard perf gates (speedup floors,
  0-alloc) live inside the bench binaries themselves.
* `--gate PCT`: timing regressions beyond PCT percent are still
  warn-only (shared CI runners are too noisy to gate wallclock), but
  STRUCTURAL regressions fail the build with exit 1:
    - schema mismatch between the two snapshots, and
    - coverage regression — any (section, name) entry present in the
      old snapshot but missing from the new one (a silently dropped
      bench reads as "no regression" forever otherwise).
  A missing/corrupt OLD snapshot still exits 0 (normal on first runs);
  an unreadable NEW snapshot always fails under --gate.

Stdlib only.

Usage:
    python3 bench_diff.py OLD.json NEW.json [--threshold 0.10] [--gate 25]
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        snap = json.load(fh)
    if snap.get("schema") not in (None, "rsd-bench-v1"):
        print(f"note: {path} has unexpected schema {snap.get('schema')!r}")
    return snap


def entry_map(snap: dict) -> dict[tuple[str, str], float]:
    out: dict[tuple[str, str], float] = {}
    for e in snap.get("entries", []):
        ns = e.get("ns_per_op")
        if isinstance(ns, (int, float)) and ns > 0:
            out[(e.get("section", ""), e.get("name", ""))] = float(ns)
    return out


def kernel_map(snap: dict) -> dict[tuple[str, str], float]:
    out: dict[tuple[str, str], float] = {}
    for name, rec in (snap.get("kernels") or {}).items():
        ns = rec.get("ns_per_op") if isinstance(rec, dict) else None
        if isinstance(ns, (int, float)) and ns > 0:
            out[("kernels", name)] = float(ns)
    return out


def diff(old: dict[tuple[str, str], float], new: dict[tuple[str, str], float],
         threshold: float) -> tuple[int, list[tuple[str, str]]]:
    """Returns (timing regressions beyond threshold, entries dropped)."""
    regressions = 0
    for key in sorted(set(old) & set(new)):
        section, name = key
        o, n = old[key], new[key]
        ratio = n / o - 1.0
        if ratio > threshold:
            regressions += 1
            flag = "  <-- REGRESSION"
        elif ratio < -threshold:
            flag = "  (improved)"
        else:
            continue
        print(f"  [{section}] {name}: {o:.1f} -> {n:.1f} ns/op ({ratio:+.1%}){flag}")
    only_new = sorted(set(new) - set(old))
    if only_new:
        print(f"  {len(only_new)} entr{'y' if len(only_new) == 1 else 'ies'} "
              "new in this run (no previous baseline)")
    dropped = sorted(set(old) - set(new))
    for section, name in dropped:
        print(f"  [{section}] {name}: present in old snapshot, MISSING from new")
    return regressions, dropped


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative ns_per_op increase flagged as a regression")
    ap.add_argument("--gate", type=float, default=None, metavar="PCT",
                    help="warn at PCT%% timing regressions; fail (exit 1) on "
                         "schema mismatch or dropped bench coverage")
    args = ap.parse_args()
    if args.gate is not None:
        args.threshold = args.gate / 100.0
    try:
        old_snap = load(args.old)
    except (OSError, json.JSONDecodeError) as exc:
        # missing/corrupt previous snapshot is normal on first runs
        print(f"bench_diff: skipping ({exc})")
        return 0
    try:
        new_snap = load(args.new)
    except (OSError, json.JSONDecodeError) as exc:
        if args.gate is not None:
            print(f"bench_diff: FAIL — new snapshot unreadable ({exc})")
            return 1
        print(f"bench_diff: skipping ({exc})")
        return 0

    mode = "gated" if args.gate is not None else "warn-only"
    print(f"bench trend: {args.old} -> {args.new} "
          f"(threshold {args.threshold:.0%}, {mode})")

    failures: list[str] = []
    old_schema = old_snap.get("schema")
    new_schema = new_snap.get("schema")
    if old_schema != new_schema:
        msg = f"schema mismatch: {old_schema!r} -> {new_schema!r}"
        print(f"  {msg}")
        failures.append(msg)

    total = 0
    dropped_all: list[tuple[str, str]] = []
    for pair in (
        (entry_map(old_snap), entry_map(new_snap)),
        (kernel_map(old_snap), kernel_map(new_snap)),
    ):
        regs, dropped = diff(pair[0], pair[1], args.threshold)
        total += regs
        dropped_all.extend(dropped)
    if dropped_all:
        failures.append(
            f"{len(dropped_all)} bench entr"
            f"{'y' if len(dropped_all) == 1 else 'ies'} dropped from coverage")

    if total:
        print(f"bench_diff: {total} entr{'y' if total == 1 else 'ies'} "
              f"regressed >{args.threshold:.0%} (timings are warn-only)")
    else:
        print("bench_diff: no timing regressions beyond threshold")

    if args.gate is not None and failures:
        for f in failures:
            print(f"bench_diff: FAIL — {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
