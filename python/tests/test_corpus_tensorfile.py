"""Substrate tests: corpus generator determinism + tensorfile round-trip."""

import numpy as np
import pytest

from compile import corpus, tensorfile


def test_corpus_deterministic():
    a = corpus.generate(0, 2000)
    b = corpus.generate(0, 2000)
    assert a == b
    c = corpus.generate(1, 2000)
    assert a != c


def test_corpus_tokens_in_alphabet():
    toks = np.frombuffer(corpus.generate(0, 5000), dtype=np.uint8)
    assert toks.max() < len(corpus.ALPHABET)
    # word-like structure: spaces occur with plausible frequency
    space = corpus.ALPHABET.index(" ")
    frac = (toks == space).mean()
    assert 0.05 < frac < 0.5


def test_corpus_has_learnable_structure():
    """A trigram source must beat the unigram entropy by a wide margin."""
    toks = np.frombuffer(corpus.generate(0, 60_000), dtype=np.uint8)
    # unigram entropy
    p = np.bincount(toks, minlength=32) / len(toks)
    h_uni = -(p[p > 0] * np.log2(p[p > 0])).sum()
    # conditional entropy given previous 2 chars
    ctx = toks[:-2] * 32 + toks[1:-1]
    nxt = toks[2:]
    h_cond = 0.0
    for c in np.unique(ctx):
        sel = nxt[ctx == c]
        q = np.bincount(sel, minlength=32) / len(sel)
        h = -(q[q > 0] * np.log2(q[q > 0])).sum()
        h_cond += h * len(sel) / len(nxt)
    assert h_cond < h_uni - 1.0, (h_cond, h_uni)


def test_tensorfile_roundtrip(tmp_path):
    path = str(tmp_path / "t.tensors")
    tensors = {
        "a": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
        "b": np.array([[1, -2], [3, 4]], dtype=np.int32),
        "scalarish": np.zeros((1,), dtype=np.float32),
    }
    tensorfile.save(path, tensors)
    out = tensorfile.load(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_tensorfile_rejects_bad_dtype(tmp_path):
    with pytest.raises(TypeError):
        tensorfile.save(str(tmp_path / "x.tensors"),
                        {"a": np.zeros(3, dtype=np.float64)})
