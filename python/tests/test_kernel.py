"""L1 correctness: Pallas tree-attention kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/masks; assert_allclose against ref.py. This is
the core correctness signal for the kernel before it is baked into the
AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import NEG_INF, tree_attention_ref
from compile.kernels.tree_attention import tree_attention

jax.config.update("jax_enable_x64", False)


def _rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _case(seed, b, h, s, dh, m, mask_kind, mblk=64):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = _rand(ks[0], (b, h, s, dh))
    k = _rand(ks[1], (b, h, m, dh))
    v = _rand(ks[2], (b, h, m, dh))
    if mask_kind == "full":
        mask = jnp.zeros((b, s, m), dtype=jnp.float32)
    elif mask_kind == "causal":
        # token s may attend slots [0, s]: the single-sequence special case
        col = jnp.arange(m)[None, :]
        row = jnp.arange(s)[:, None]
        mask = jnp.where(col <= row, 0.0, NEG_INF)[None].repeat(b, axis=0)
    elif mask_kind == "random":
        bern = jax.random.bernoulli(ks[3], 0.5, (b, s, m))
        mask = jnp.where(bern, 0.0, NEG_INF)
        # ensure no fully-masked row explodes the comparison: let every row
        # attend slot 0
        mask = mask.at[:, :, 0].set(0.0)
    elif mask_kind == "padded":
        # last rows fully masked (padding tokens); ref gives uniform attention
        # there, kernel guards the 0-sum division — skip comparing those rows.
        bern = jax.random.bernoulli(ks[3], 0.7, (b, s, m))
        mask = jnp.where(bern, 0.0, NEG_INF)
        mask = mask.at[:, :, 0].set(0.0)
        mask = mask.at[:, s // 2:, :].set(NEG_INF)
    out = tree_attention(q, k, v, mask, mblk=mblk)
    ref = tree_attention_ref(q, k, v, mask)
    valid = s if mask_kind != "padded" else s // 2
    np.testing.assert_allclose(
        np.asarray(out)[:, :, :valid], np.asarray(ref)[:, :, :valid],
        rtol=2e-5, atol=2e-5,
    )


@pytest.mark.parametrize("mask_kind", ["full", "causal", "random", "padded"])
def test_kernel_matches_ref_model_shapes(mask_kind):
    # the exact shapes the target model feeds the kernel
    _case(0, b=1, h=4, s=32, dh=64, m=256, mask_kind=mask_kind)


@pytest.mark.parametrize("mask_kind", ["full", "causal", "random"])
def test_kernel_matches_ref_draft_shapes(mask_kind):
    # draft model shapes
    _case(1, b=1, h=2, s=32, dh=32, m=256, mask_kind=mask_kind)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s=st.sampled_from([1, 2, 7, 16, 32]),
    dh=st.sampled_from([8, 16, 64]),
    mblocks=st.integers(1, 4),
    mask_kind=st.sampled_from(["full", "causal", "random"]),
)
def test_kernel_matches_ref_hypothesis(seed, b, h, s, dh, mblocks, mask_kind):
    mblk = 16
    _case(seed, b=b, h=h, s=s, dh=dh, m=mblk * mblocks, mask_kind=mask_kind, mblk=mblk)


def test_kernel_rejects_unaligned_cache():
    q = jnp.zeros((1, 1, 4, 8))
    k = jnp.zeros((1, 1, 65, 8))
    with pytest.raises(ValueError):
        tree_attention(q, k, k, jnp.zeros((1, 4, 65)), mblk=64)


def test_kernel_is_jittable_and_lowers_to_hlo():
    """interpret=True must inline into plain HLO (no python at runtime)."""
    fn = jax.jit(lambda q, k, v, m: tree_attention(q, k, v, m, mblk=16))
    q = jnp.ones((1, 2, 8, 16))
    k = jnp.ones((1, 2, 32, 16))
    m = jnp.zeros((1, 8, 32))
    lowered = fn.lower(q, k, k, m)
    hlo = lowered.compiler_ir(dialect="hlo").as_hlo_text()
    assert "custom-call" not in hlo.lower(), "Mosaic custom-call leaked into HLO"
    out = fn(q, k, k, m)
    assert out.shape == (1, 2, 8, 16)
