"""L2 correctness: step-executable semantics.

The critical invariant: incremental decoding through the KV cache (the
serving path) must reproduce the full causal forward (the training path),
and evaluating a draft *tree* in one call must equal evaluating each
branch as a separate sequence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import DRAFT, ModelConfig
from compile.kernels.ref import NEG_INF

CFG = ModelConfig(name="test", vocab=64, n_layers=2, d_model=32, n_heads=2,
                  d_ff=64, s_tile=8, cache_len=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(3))


def pad_to(x, n, fill):
    return np.concatenate([x, np.full(n - len(x), fill, dtype=x.dtype)])


def run_step(params, tokens, positions, dest, mask_rows, kc, vc,
             use_pallas=False):
    """mask_rows: [len(tokens), M] boolean visibility."""
    S, Mlen = CFG.s_tile, CFG.cache_len
    n = len(tokens)
    t = jnp.asarray(pad_to(np.asarray(tokens, np.int32), S, 0))[None]
    p = jnp.asarray(pad_to(np.asarray(positions, np.int32), S, 0))[None]
    d = jnp.asarray(pad_to(np.asarray(dest, np.int32), S, Mlen - 1))[None]
    m = np.full((S, Mlen), NEG_INF, np.float32)
    m[:n] = np.where(mask_rows, 0.0, NEG_INF)
    logits, kc, vc = M.step(CFG, params, t, p, d, jnp.asarray(m)[None],
                            kc, vc, use_pallas=use_pallas)
    return np.asarray(logits)[0, :n], kc, vc


def test_incremental_decode_matches_causal(params):
    """Prefill+decode through the cache == full causal forward."""
    T = 20
    toks = np.arange(T) % CFG.vocab
    full = np.asarray(M.causal_logits(CFG, params, jnp.asarray(toks[None], jnp.int32)))[0]

    kc, vc = M.empty_cache(CFG)
    Mlen = CFG.cache_len
    # prefill first 12 tokens in chunks of s_tile=8, then decode one by one
    got = []
    pos = 0
    for chunk in (toks[:8], toks[8:12]):
        n = len(chunk)
        positions = np.arange(pos, pos + n)
        dest = positions
        rows = np.zeros((n, Mlen), bool)
        for i in range(n):
            rows[i, :pos + i + 1] = True
        lg, kc, vc = run_step(params, chunk, positions, dest, rows, kc, vc)
        got.append(lg)
        pos += n
    for t in range(12, T):
        rows = np.zeros((1, Mlen), bool)
        rows[0, :t + 1] = True
        lg, kc, vc = run_step(params, toks[t:t + 1], [t], [t], rows, kc, vc)
        got.append(lg)
    got = np.concatenate(got, axis=0)
    np.testing.assert_allclose(got, full, rtol=2e-4, atol=2e-4)


def test_tree_eval_matches_per_branch(params):
    """One tree call == per-branch sequential eval.

    Tree over prefix [5, 9]:        level 1: a=3, b=7 (siblings)
                                    level 2: a->c=1, b->d=2
    Flattened tree tokens [3, 7, 1, 2] evaluated in ONE call with the
    topology mask must match evaluating sequences [5,9,3,1] and [5,9,7,2]
    token-by-token.
    """
    Mlen = CFG.cache_len
    prefix = np.array([5, 9])

    # ---- reference: two independent sequential decodes
    def decode_seq(seq):
        kc, vc = M.empty_cache(CFG)
        outs = []
        for t, tok in enumerate(seq):
            rows = np.zeros((1, Mlen), bool)
            rows[0, :t + 1] = True
            lg, kc, vc = run_step(params, [tok], [t], [t], rows, kc, vc)
            outs.append(lg[0])
        return np.stack(outs)

    seq_a = decode_seq([5, 9, 3, 1])
    seq_b = decode_seq([5, 9, 7, 2])

    # ---- tree path: prefill prefix, then one call with 4 tree tokens
    kc, vc = M.empty_cache(CFG)
    rows = np.zeros((2, Mlen), bool)
    rows[0, :1] = True
    rows[1, :2] = True
    lg_prefix, kc, vc = run_step(params, prefix, [0, 1], [0, 1], rows, kc, vc)

    # flat tree: slots 2..5 hold tokens [3, 7, 1, 2]
    toks = [3, 7, 1, 2]
    positions = [2, 2, 3, 3]
    dest = [2, 3, 4, 5]
    vis = np.zeros((4, Mlen), bool)
    vis[0, [0, 1, 2]] = True          # a sees prefix + self
    vis[1, [0, 1, 3]] = True          # b sees prefix + self
    vis[2, [0, 1, 2, 4]] = True       # c sees prefix + a + self
    vis[3, [0, 1, 3, 5]] = True       # d sees prefix + b + self
    lg_tree, _, _ = run_step(params, toks, positions, dest, vis, kc, vc)

    np.testing.assert_allclose(lg_prefix[1], seq_a[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(lg_tree[0], seq_a[2], rtol=2e-4, atol=2e-4)  # after a
    np.testing.assert_allclose(lg_tree[1], seq_b[2], rtol=2e-4, atol=2e-4)  # after b
    np.testing.assert_allclose(lg_tree[2], seq_a[3], rtol=2e-4, atol=2e-4)  # after c
    np.testing.assert_allclose(lg_tree[3], seq_b[3], rtol=2e-4, atol=2e-4)  # after d


def test_pallas_and_ref_step_agree(params):
    """The AOT artifact uses the Pallas kernel; training used ref. Equal."""
    cfg = ModelConfig(name="t2", vocab=64, n_layers=2, d_model=32, n_heads=2,
                      d_ff=64, s_tile=8, cache_len=64)
    kc, vc = M.empty_cache(cfg)
    toks = jnp.asarray(np.arange(8, dtype=np.int32))[None]
    pos = toks
    dest = toks
    rows = np.tril(np.ones((8, 64), np.float32), 0)
    mask = jnp.asarray(np.where(rows[:, :64] > 0, 0.0, NEG_INF))[None]
    lg_ref, _, _ = M.step(cfg, params, toks, pos, dest, mask, kc, vc,
                          use_pallas=False)
    lg_pal, _, _ = M.step(cfg, params, toks, pos, dest, mask, kc, vc,
                          use_pallas=True)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_pal),
                               rtol=2e-4, atol=2e-4)


def test_padding_tokens_do_not_corrupt_cache(params):
    """Padding rows write KV to the scratch slot M-1 and change nothing
    observable: logits for real tokens are identical with or without
    trailing padding junk."""
    Mlen = CFG.cache_len
    kc, vc = M.empty_cache(CFG)
    rows = np.zeros((3, Mlen), bool)
    for i in range(3):
        rows[i, :i + 1] = True
    lg_a, kca, vca = run_step(params, [1, 2, 3], [0, 1, 2], [0, 1, 2], rows, kc, vc)

    # same call but padded tile carries junk tokens pointing at slot M-1
    S = CFG.s_tile
    t = np.array([1, 2, 3] + [42] * (S - 3), np.int32)[None]
    p = np.array([0, 1, 2] + [7] * (S - 3), np.int32)[None]
    d = np.array([0, 1, 2] + [Mlen - 1] * (S - 3), np.int32)[None]
    m = np.full((S, Mlen), NEG_INF, np.float32)
    m[:3] = np.where(rows, 0.0, NEG_INF)
    kc, vc = M.empty_cache(CFG)
    lg_b, kcb, vcb = M.step(CFG, params, jnp.asarray(t), jnp.asarray(p),
                            jnp.asarray(d), jnp.asarray(m)[None], kc, vc,
                            use_pallas=False)
    np.testing.assert_allclose(lg_a, np.asarray(lg_b)[0, :3], rtol=1e-5, atol=1e-5)
    # real cache slots identical
    np.testing.assert_allclose(np.asarray(kca)[:, :, :, :3],
                               np.asarray(kcb)[:, :, :, :3], rtol=1e-6, atol=1e-6)


def test_cache_scatter_writes_expected_slots(params):
    kc, vc = M.empty_cache(CFG)
    rows = np.zeros((2, CFG.cache_len), bool)
    rows[0, 10] = True
    rows[1, 20] = True
    _, kc, vc = run_step(params, [1, 2], [0, 0], [10, 20], rows, kc, vc)
    kc = np.asarray(kc)
    assert np.abs(kc[:, :, :, 10]).sum() > 0
    assert np.abs(kc[:, :, :, 20]).sum() > 0
    assert np.abs(kc[:, :, :, 11:20]).sum() == 0
