"""AOT export checks: HLO text structure + manifest consistency.

Uses skip-train mode (random weights) — the export path itself is what is
under test; the trained artifacts are built by `make artifacts`.
"""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def smoke_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("aot"))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, "--skip-train"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    return out


def test_manifest_shape(smoke_artifacts):
    with open(os.path.join(smoke_artifacts, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == {"target", "draft"}
    for name, m in man["models"].items():
        assert os.path.exists(os.path.join(smoke_artifacts, m["hlo"]))
        assert os.path.exists(os.path.join(smoke_artifacts, m["tensors"]))
        assert m["input_order"][-6:] == [
            "tokens", "positions", "dest", "attn_mask", "kcache", "vcache"]
        assert set(m["tiles"]) == {"1", "4", "8", "16", "32"}
        assert m["cache_len"] % 64 == 0  # MBLK alignment
        assert m["s_tile"] >= 30         # max paper budget fits one tile


def test_hlo_text_is_parseable_shape(smoke_artifacts):
    """HLO text sanity for every tile variant: ENTRY present, no
    custom-calls (Mosaic would break the CPU PJRT client)."""
    import glob

    for name in ("target", "draft"):
        paths = glob.glob(os.path.join(smoke_artifacts, f"{name}_step_s*.hlo.txt"))
        assert len(paths) >= 3, "expected multiple tile variants"
        for path in paths:
            with open(path) as f:
                hlo = f.read()
            assert "ENTRY" in hlo
            assert "custom-call" not in hlo.lower()
            assert "f32[" in hlo


def test_weights_match_model_shapes(smoke_artifacts):
    from compile import tensorfile
    from compile.configs import DRAFT, TARGET

    for cfg in (TARGET, DRAFT):
        t = tensorfile.load(os.path.join(smoke_artifacts, f"{cfg.name}.tensors"))
        L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
        assert t["tok_emb"].shape == (V, D)
        assert t["w_q"].shape == (L, D, D)
        assert t["w_gate"].shape == (L, D, F)
        assert t["unemb"].shape == (D, V)
