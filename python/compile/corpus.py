"""Synthetic tiny-corpus generator.

The paper evaluates on WMT/XSum/Dolly, which are unavailable here
(DESIGN.md §2). Block efficiency depends on the *draft-target
distributional discrepancy*, not on the corpus itself, so we substitute a
seeded character-level source with real learnable structure: a sparse
trigram ("Markov English") model over a 32-symbol alphabet with Zipfian
marginals and word-like segmentation. The target LM learns it well; the
2-layer draft learns it imperfectly — reproducing the alignment regime
the paper's distilled drafters sit in (App. C.1).

Deterministic per seed. Emitted as raw bytes (tokens ARE bytes).
"""

import numpy as np

ALPHABET = "abcdefghijklmnopqrstuvwxyz .,;\n'"
assert len(ALPHABET) == 32


def build_trigram(seed: int):
    """Sparse trigram transition table over the alphabet.

    For each (c1, c2) context: 6 permitted successors with Dirichlet
    weights, biased so that ' ' terminates words at plausible lengths.
    """
    rng = np.random.default_rng(seed)
    n = len(ALPHABET)
    space = ALPHABET.index(" ")
    succ = np.zeros((n, n, n), dtype=np.float64)
    for a in range(n):
        for b in range(n):
            k = 6
            choices = rng.choice(n, size=k, replace=False)
            w = rng.dirichlet(np.full(k, 0.4))
            succ[a, b, choices] = w
            # word-boundary pressure: after 2 letters, some mass to space
            if b != space:
                succ[a, b, space] += 0.12
            succ[a, b] /= succ[a, b].sum()
    return succ


def generate(seed: int, n_chars: int) -> bytes:
    """Sample n_chars from the trigram source; returns token bytes 0..31."""
    rng = np.random.default_rng(seed + 1)
    table = build_trigram(seed)
    n = len(ALPHABET)
    out = np.empty(n_chars, dtype=np.uint8)
    a, b = 0, 1
    # vectorised-ish sampling: draw uniforms in bulk, walk the chain
    us = rng.random(n_chars)
    for i in range(n_chars):
        cdf = np.cumsum(table[a, b])
        c = int(np.searchsorted(cdf, us[i]))
        c = min(c, n - 1)
        out[i] = c
        a, b = b, c
    return out.tobytes()


def to_text(tokens: bytes) -> str:
    return "".join(ALPHABET[t] for t in tokens)
