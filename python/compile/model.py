"""Layer-2 JAX model: Llama-style transformer with tree attention.

One `step` function serves every phase of speculative decoding (DESIGN.md
§1): prefill chunks, single-token decode, per-level draft-tree expansion
and the target pass over the whole flattened tree. The Rust coordinator
owns the semantics — it supplies position ids, KV scatter destinations and
the {0,-inf} topology mask; the model is a pure tensor program.

Contract (static shapes; B=batch, S=s_tile, M=cache_len):

  step(params,
       tokens    i32[B, S],
       positions i32[B, S],
       dest      i32[B, S],        # KV-cache scatter slots; pad -> M-1
       attn_mask f32[B, S, M],
       kcache    f32[L, B, H, M, Dh],
       vcache    f32[L, B, H, M, Dh])
    -> (logits f32[B, S, V], kcache', vcache')

Weights travel as runtime inputs (stacked per kind across layers) so the
HLO text stays small and one executable serves any checkpoint of the same
shape — Rust loads them from artifacts/*.tensors.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.ref import tree_attention_ref
from .kernels.tree_attention import tree_attention


# KV-cache storage dtype. bf16 would halve HBM traffic on a real TPU, but
# this testbed's CPU PJRT *emulates* bf16 in software: measured step
# latency got worse (7.3ms vs 6.1ms), so f32 is kept here and the bf16
# switch stays one line away (EXPERIMENTS.md §Perf iteration 3).
CACHE_DTYPE = jnp.float32


class Params(NamedTuple):
    """Flattened weights; every field is one runtime input of the HLO."""

    tok_emb: jax.Array   # [V, D]
    w_q: jax.Array       # [L, D, D]
    w_k: jax.Array       # [L, D, D]
    w_v: jax.Array       # [L, D, D]
    w_o: jax.Array       # [L, D, D]
    w_gate: jax.Array    # [L, D, F]
    w_up: jax.Array      # [L, D, F]
    w_down: jax.Array    # [L, F, D]
    rms_attn: jax.Array  # [L, D]
    rms_ffn: jax.Array   # [L, D]
    rms_out: jax.Array   # [D]
    unemb: jax.Array     # [D, V]


PARAM_FIELDS = list(Params._fields)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Scaled-normal init (1/sqrt(fan_in); residual projections down-scaled)."""
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    ks = jax.random.split(key, 9)
    resid_scale = 1.0 / (2.0 * L) ** 0.5

    def nrm(k, shape, fan_in, scale=1.0):
        return (jax.random.normal(k, shape, dtype=jnp.float32)
                * (scale / fan_in ** 0.5))

    return Params(
        tok_emb=nrm(ks[0], (V, D), 1.0, 0.02 * D ** 0.5),
        w_q=nrm(ks[1], (L, D, D), D),
        w_k=nrm(ks[2], (L, D, D), D),
        w_v=nrm(ks[3], (L, D, D), D),
        w_o=nrm(ks[4], (L, D, D), D, resid_scale),
        w_gate=nrm(ks[5], (L, D, F), D),
        w_up=nrm(ks[6], (L, D, F), D),
        w_down=nrm(ks[7], (L, F, D), F, resid_scale),
        rms_attn=jnp.ones((L, D)),
        rms_ffn=jnp.ones((L, D)),
        rms_out=jnp.ones((D,)),
        unemb=nrm(ks[8], (D, V), D),
    )


def _rmsnorm(x, g):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _rope(x, positions, theta: float):
    """Rotary embedding from explicit position ids. x: [B, H, S, Dh]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _scatter_kv(cache, dest, new):
    """cache: [B,H,M,Dh] (cache dtype); dest: [B,S]; new: [B,H,S,Dh].

    Padding tokens carry dest == M-1 (the reserved scratch slot, never
    attended), so their writes are harmless. The cache is stored in
    CACHE_DTYPE (bf16): halves the per-call cache traffic that dominates
    small-tile step latency (EXPERIMENTS.md §Perf iteration 3).
    """
    b = cache.shape[0]
    bidx = jnp.arange(b)[:, None]                       # [B,1] -> bcast [B,S]
    return cache.at[bidx, :, dest].set(
        new.transpose(0, 2, 1, 3).astype(cache.dtype))


def step(cfg: ModelConfig, params: Params, tokens, positions, dest,
         attn_mask, kcache, vcache, *, use_pallas: bool = True):
    """One forward pass over S tree tokens. See module docstring."""
    B, S = tokens.shape
    H, Dh = cfg.n_heads, cfg.d_head
    x = params.tok_emb[tokens]  # [B, S, D]
    attend = tree_attention if use_pallas else tree_attention_ref

    def layer(x, xs):
        (wq, wk, wv, wo, wg, wu, wd, g1, g2, kc, vc) = xs
        h = _rmsnorm(x, g1)
        q = (h @ wq).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        k = (h @ wk).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        v = (h @ wv).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        kc = _scatter_kv(kc, dest, k)
        vc = _scatter_kv(vc, dest, v)
        att = attend(q, kc.astype(jnp.float32), vc.astype(jnp.float32),
                     attn_mask)                         # [B, H, S, Dh]
        att = att.transpose(0, 2, 1, 3).reshape(B, S, -1)
        x = x + att @ wo
        h2 = _rmsnorm(x, g2)
        x = x + (jax.nn.silu(h2 @ wg) * (h2 @ wu)) @ wd
        return x, (kc, vc)

    xs = (params.w_q, params.w_k, params.w_v, params.w_o,
          params.w_gate, params.w_up, params.w_down,
          params.rms_attn, params.rms_ffn, kcache, vcache)
    x, (kc, vc) = jax.lax.scan(layer, x, xs)
    logits = _rmsnorm(x, params.rms_out) @ params.unemb
    return logits, kc, vc


def empty_cache(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.batch, cfg.n_heads, cfg.cache_len, cfg.d_head)
    return jnp.zeros(shape, CACHE_DTYPE), jnp.zeros(shape, CACHE_DTYPE)


# ---------------------------------------------------------------------------
# Training-time forward: full causal sequence, no external cache.
# Reuses `step` with M == seq_len so train and serve share one code path.
# ---------------------------------------------------------------------------

def causal_logits(cfg: ModelConfig, params: Params, tokens,
                  *, use_pallas: bool = False):
    """tokens: i32[B, T] -> logits f32[B, T, V] under plain causal masking."""
    from .kernels.ref import NEG_INF

    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    dest = positions
    col = jnp.arange(T)[None, :]
    row = jnp.arange(T)[:, None]
    mask = jnp.where(col <= row, 0.0, NEG_INF)[None]
    mask = jnp.broadcast_to(mask, (B, T, T)).astype(jnp.float32)
    shape = (cfg.n_layers, B, cfg.n_heads, T, cfg.d_head)
    kc = jnp.zeros(shape, CACHE_DTYPE)
    vc = jnp.zeros(shape, CACHE_DTYPE)
    train_cfg = cfg
    logits, _, _ = step(train_cfg, params, tokens, positions, dest, mask,
                        kc, vc, use_pallas=use_pallas)
    return logits
