"""safetensors-lite: the weights interchange format between python and rust.

Layout:  [8-byte LE u64 header_len][header JSON utf-8][raw tensor data]
Header:  {"name": {"dtype": "f32", "shape": [..], "offset": N, "nbytes": M}, ...}
Offsets are relative to the start of the data section; tensors are raw
little-endian, C-contiguous. Reader lives in rust/src/tensorfile.rs.
"""

import json
import struct

import numpy as np

_DTYPES = {"f32": np.float32, "i32": np.int32}


def save(path: str, tensors: dict):
    header = {}
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float32:
            dt = "f32"
        elif arr.dtype == np.int32:
            dt = "i32"
        else:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes()
        header[name] = {
            "dtype": dt,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
        blobs.append(raw)
        offset += len(raw)
    hjson = json.dumps(header, sort_keys=True).encode("utf-8")
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


def load(path: str) -> dict:
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode("utf-8"))
        data = f.read()
    out = {}
    for name, meta in header.items():
        dt = _DTYPES[meta["dtype"]]
        raw = data[meta["offset"]:meta["offset"] + meta["nbytes"]]
        out[name] = np.frombuffer(raw, dtype=dt).reshape(meta["shape"]).copy()
    return out
