"""Build-time training: target LM on the synthetic corpus, draft by
distillation from the target (paper App. C.1's recipe, scaled down).

Runs once under `make artifacts`; never on the request path. Loss curves
are logged to artifacts/train_log.json for EXPERIMENTS.md.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import model as M
from .configs import DRAFT, TARGET, TRAIN, ModelConfig, TrainConfig


def batches(tokens: np.ndarray, tc: TrainConfig, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - tc.seq_len - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=tc.batch)
        x = np.stack([tokens[i:i + tc.seq_len] for i in idx])
        y = np.stack([tokens[i + 1:i + tc.seq_len + 1] for i in idx])
        yield jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def _adamw_update(p, g, m, v, step, lr, wd=0.01, b1=0.9, b2=0.99, eps=1e-8):
    m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
    v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
    t = step + 1
    def upd(p_, m_, v_):
        mh = m_ / (1 - b1 ** t)
        vh = v_ / (1 - b2 ** t)
        return p_ - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p_)
    return jax.tree.map(upd, p, m, v), m, v


def _lr(step, tc: TrainConfig, total: int):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, total - tc.warmup), 0.0, 1.0)
    return tc.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * prog)))


def train_target(cfg: ModelConfig, tc: TrainConfig, tokens: np.ndarray):
    params = M.init_params(cfg, jax.random.PRNGKey(tc.seed))

    def loss_fn(p, x, y):
        logits = M.causal_logits(cfg, p, x, use_pallas=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    @jax.jit
    def train_step(p, m, v, step, x, y):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        lr = _lr(step, tc, tc.target_steps)
        p, m, v = _adamw_update(p, g, m, v, step, lr)
        return p, m, v, loss

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    log = []
    t0 = time.time()
    for i, (x, y) in enumerate(batches(tokens, tc, tc.target_steps, tc.seed)):
        params, m, v, loss = train_step(params, m, v, i, x, y)
        if i % 20 == 0 or i == tc.target_steps - 1:
            log.append({"step": i, "loss": float(loss)})
            print(f"[target] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    return params, log


def distill_draft(draft_cfg: ModelConfig, target_cfg: ModelConfig,
                  target_params, tc: TrainConfig, tokens: np.ndarray):
    """Draft trains to match the target's next-token distribution (KL)."""
    params = M.init_params(draft_cfg, jax.random.PRNGKey(tc.seed + 7))

    @jax.jit
    def teacher_logp(x):
        lg = M.causal_logits(target_cfg, target_params, x, use_pallas=False)
        return jax.nn.log_softmax(lg, axis=-1)

    def loss_fn(p, x, tlogp):
        logits = M.causal_logits(draft_cfg, p, x, use_pallas=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.mean(jnp.sum(jnp.exp(tlogp) * (tlogp - logp), axis=-1))

    @jax.jit
    def train_step(p, m, v, step, x, tlogp):
        loss, g = jax.value_and_grad(loss_fn)(p, x, tlogp)
        lr = _lr(step, tc, tc.draft_steps)
        p, m, v = _adamw_update(p, g, m, v, step, lr)
        return p, m, v, loss

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    log = []
    t0 = time.time()
    for i, (x, _) in enumerate(batches(tokens, tc, tc.draft_steps, tc.seed + 7)):
        tlogp = teacher_logp(x)
        params, m, v, loss = train_step(params, m, v, i, x, tlogp)
        if i % 20 == 0 or i == tc.draft_steps - 1:
            log.append({"step": i, "kl": float(loss)})
            print(f"[draft ] step {i:4d} KL {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    return params, log


def run(tc: TrainConfig = TRAIN):
    raw = corpus_mod.generate(tc.seed, tc.corpus_chars)
    tokens = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
    target_params, tlog = train_target(TARGET, tc, tokens)
    draft_params, dlog = distill_draft(DRAFT, TARGET, target_params, tc, tokens)
    return raw, target_params, draft_params, {"target": tlog, "draft": dlog}
