"""Layer-1 Pallas kernel: masked tree-attention over the KV cache.

The paper's hot spot is the parallel evaluation of the draft-token tree
(§3.2.2): one transformer pass where S "tree" tokens attend to the full
KV cache under an arbitrary topology mask. On GPU the authors express the
tree with threadblock attention masking; the TPU translation (DESIGN.md
§5) is a VMEM-tiled, online-softmax (flash-style) attention kernel:

  * grid over (batch, head) — each program owns one [S, Dh] query tile;
  * keys/values/mask stream in M-blocks of MBLK slots; the running
    (max, sum, accumulator) online-softmax state means the full [S, M]
    score matrix never materialises in VMEM;
  * the {0, -inf} tree mask streams with the K/V tiles, so irregular tree
    topology costs one extra VMEM stream and zero control-flow divergence
    — the MXU contraction stays dense.

Must run with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Under jit-tracing,
interpret mode inlines the kernel into plain HLO, so the *runtime* path
(Rust + PJRT) never touches Python.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# M-block width. 64 slots x Dh<=64 keeps each streamed tile
# (k, v: MBLK x Dh, mask: S x MBLK) around 16-32 KiB — far under VMEM,
# leaving room for double-buffering on real hardware. See EXPERIMENTS.md
# §Perf for the footprint table.
MBLK = 64


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, mblk: int):
    """One (batch, head) program: online-softmax attention over M blocks."""
    q = q_ref[0, 0]            # [S, Dh]
    k = k_ref[0, 0]            # [M, Dh]
    v = v_ref[0, 0]            # [M, Dh]
    mask = mask_ref[0]         # [S, M]
    s, dh = q.shape
    m = k.shape[0]
    nblk = m // mblk
    scale = (1.0 / (dh ** 0.5)).__float__()

    def body(i, carry):
        m_run, l_run, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * mblk, mblk, axis=0)      # [MBLK, Dh]
        vb = jax.lax.dynamic_slice_in_dim(v, i * mblk, mblk, axis=0)      # [MBLK, Dh]
        mb = jax.lax.dynamic_slice_in_dim(mask, i * mblk, mblk, axis=1)   # [S, MBLK]
        scores = q @ kb.T * scale + mb                                    # [S, MBLK]
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))              # [S]
        corr = jnp.exp(m_run - m_new)                                     # [S]
        p = jnp.exp(scores - m_new[:, None])                              # [S, MBLK]
        l_new = l_run * corr + jnp.sum(p, axis=-1)                        # [S]
        acc = acc * corr[:, None] + p @ vb                                # [S, Dh]
        return m_new, l_new, acc

    m0 = jnp.full((s,), NEG_INF, dtype=q.dtype)
    l0 = jnp.zeros((s,), dtype=q.dtype)
    a0 = jnp.zeros((s, dh), dtype=q.dtype)
    m_fin, l_fin, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, a0))
    # fully-masked (padding) rows have l == 0; guard the division — their
    # output is never read by the coordinator.
    l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    o_ref[0, 0] = acc / l_safe[:, None]


def tree_attention(q, k, v, mask, *, mblk: int = MBLK, interpret: bool = True):
    """Pallas tree-attention. Shapes as in ref.tree_attention_ref.

    q: [B, H, S, Dh]; k, v: [B, H, M, Dh]; mask: [B, S, M] additive.
    Returns [B, H, S, Dh].
    """
    b, h, s, dh = q.shape
    m = k.shape[2]
    if m % mblk != 0:
        raise ValueError(f"cache_len {m} must be a multiple of mblk {mblk}")
    kernel = functools.partial(_attention_kernel, mblk=mblk)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, m, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, m, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, s, m), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)
