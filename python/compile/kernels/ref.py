"""Pure-jnp oracle for the tree-attention kernel.

This is the CORE correctness signal for Layer 1: the Pallas kernel in
`tree_attention.py` must match `tree_attention_ref` to float tolerance for
every shape/mask the model can feed it (pytest + hypothesis sweeps in
python/tests/test_kernel.py).
"""

import jax.numpy as jnp

NEG_INF = -1e30  # additive mask value for "cannot attend"


def tree_attention_ref(q, k, v, mask):
    """Masked attention over a KV cache with an arbitrary (tree) mask.

    Args:
      q:    [B, H, S, Dh] queries for the S new (tree) tokens.
      k:    [B, H, M, Dh] full KV cache keys (new tokens already scattered).
      v:    [B, H, M, Dh] full KV cache values.
      mask: [B, S, M] additive mask, 0 where token s may attend cache slot m,
            <= NEG_INF where it may not. Built by the Rust coordinator from
            the draft-tree topology (paper Alg. 5 BuildAttentionMask).

    Returns:
      [B, H, S, Dh] attention output.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bhsd,bhmd->bhsm", q, k) * scale
    scores = scores + mask[:, None, :, :]
    # stable softmax; fully-masked rows (padding tokens) become uniform,
    # which is harmless: their output is never read.
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhsm,bhmd->bhsd", w, v)
