"""L1/L2 performance analysis (EXPERIMENTS.md §Perf).

interpret=True gives CPU-numpy semantics, so L1 performance on real TPU is
*estimated from structure*: VMEM footprint of the BlockSpec tiling, MXU
shape utilization of the contractions, and HBM<->VMEM traffic per step.
L2 is profiled via the lowered HLO text: op census, fusion check, and an
analytic FLOP/byte roofline for the step executable.

    python -m compile.perf_analysis [--artifacts ../artifacts]
"""

import argparse
import os
import re
from collections import Counter

from .configs import DRAFT, TARGET, ModelConfig
from .kernels.tree_attention import MBLK

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on contemporary TPUs
MXU = 128                      # systolic array dimension


def kernel_analysis(cfg: ModelConfig):
    """VMEM footprint + MXU utilization of the tree-attention kernel."""
    S, Dh, M = cfg.s_tile, cfg.d_head, cfg.cache_len
    f = 4  # f32 bytes (bf16 on real TPU would halve this)
    q_tile = S * Dh * f
    k_blk = MBLK * Dh * f
    v_blk = MBLK * Dh * f
    mask_blk = S * MBLK * f
    score = S * MBLK * f
    acc = S * Dh * f + 2 * S * f
    # double-buffered streams: k, v, mask
    total = q_tile + 2 * (k_blk + v_blk + mask_blk) + score + acc
    # MXU utilization: contraction shapes vs the 128x128 array
    #   scores: [S, Dh] @ [Dh, MBLK]  -> S x Dh x MBLK
    #   out:    [S, MBLK] @ [MBLK, Dh]
    def mxu_util(m, k, n):
        return (min(m, MXU) / MXU) * (min(k, MXU) / MXU) * (min(n, MXU) / MXU) ** 0

    util_scores = (min(S, MXU) / MXU) * (min(Dh, MXU) / MXU)
    util_out = (min(S, MXU) / MXU) * (min(MBLK, MXU) / MXU)
    hbm_per_step = (S * Dh + 2 * M * Dh + S * M) * f  # q + k/v cache + mask
    flops = 2 * S * M * Dh * 2  # qk^T and attn@v
    return {
        "S": S, "Dh": Dh, "M": M, "MBLK": MBLK,
        "vmem_bytes": total,
        "vmem_frac": total / VMEM_BYTES,
        "mxu_util_scores": util_scores,
        "mxu_util_out": util_out,
        "hbm_bytes_per_head": hbm_per_step,
        "flops_per_head": flops,
        "arithmetic_intensity": flops / hbm_per_step,
    }


def hlo_census(path: str):
    """Op census of the lowered step HLO: fusion coverage, convolution/dot
    count, while-loop (layer scan) presence, any stray custom-calls."""
    with open(path) as f:
        text = f.read()
    ops = Counter()
    for m in re.finditer(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]<>{},\s]*?\s([a-z][a-z0-9\-]*)\(",
                         text, re.M):
        ops[m.group(1)] += 1
    n_while = text.count("while(")
    return ops, len(text.splitlines()), n_while


def model_flops(cfg: ModelConfig):
    """Analytic FLOPs of one step call (S tokens through the stack)."""
    S, D, F, L, V, M, H, Dh = (cfg.s_tile, cfg.d_model, cfg.d_ff, cfg.n_layers,
                               cfg.vocab, cfg.cache_len, cfg.n_heads, cfg.d_head)
    attn_proj = 4 * 2 * S * D * D
    attn_core = H * (2 * 2 * S * M * Dh)
    ffn = 2 * S * (2 * D * F + F * D)
    per_layer = attn_proj + attn_core + ffn
    unemb = 2 * S * D * V
    return L * per_layer + unemb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    print("=" * 70)
    print("L1 — Pallas tree-attention kernel: VMEM / MXU structure")
    print("=" * 70)
    for cfg in (TARGET, DRAFT):
        a = kernel_analysis(cfg)
        print(f"\n[{cfg.name}] S={a['S']} Dh={a['Dh']} M={a['M']} MBLK={a['MBLK']}")
        print(f"  VMEM footprint (double-buffered streams): "
              f"{a['vmem_bytes']/1024:.1f} KiB = {a['vmem_frac']*100:.2f}% of 16 MiB")
        print(f"  MXU utilization: scores {a['mxu_util_scores']*100:.0f}% "
              f"(S x Dh = {a['S']}x{a['Dh']} vs 128x128), "
              f"out {a['mxu_util_out']*100:.0f}%")
        print(f"  HBM traffic/head/step: {a['hbm_bytes_per_head']/1024:.1f} KiB, "
              f"arithmetic intensity {a['arithmetic_intensity']:.2f} flop/byte "
              f"(memory-bound, as the paper assumes)")

    print()
    print("=" * 70)
    print("L2 — lowered step HLO census")
    print("=" * 70)
    for cfg in (TARGET, DRAFT):
        path = os.path.join(args.artifacts, f"{cfg.name}_step.hlo.txt")
        if not os.path.exists(path):
            print(f"[{cfg.name}] artifact missing; run `make artifacts`")
            continue
        ops, lines, n_while = hlo_census(path)
        total = sum(ops.values())
        print(f"\n[{cfg.name}] {lines} HLO lines, {total} instructions")
        top = ", ".join(f"{k}:{v}" for k, v in ops.most_common(10))
        print(f"  top ops: {top}")
        print(f"  dot/convolution ops: {ops.get('dot', 0) + ops.get('convolution', 0)}")
        print(f"  while (layer scan): {n_while}; "
              f"custom-call: {ops.get('custom-call', 0)} (MUST be 0 for CPU PJRT)")
        fl = model_flops(cfg)
        print(f"  analytic step cost: {fl/1e6:.1f} MFLOPs for S={cfg.s_tile} tokens")

    print()
    print("interpretation notes:")
    print(" * interpret=True wallclock is NOT a TPU proxy; the structural")
    print("   numbers above are the optimization target for L1.")
    print(" * arithmetic intensity << MXU ridge point confirms decode is")
    print("   memory-bandwidth-bound -> MBSU is the right speedup model.")


if __name__ == "__main__":
    main()
