"""AOT export: lower the L2 step function to HLO *text* and emit all
artifacts the Rust coordinator needs.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Emits into artifacts/:
  target_step.hlo.txt, draft_step.hlo.txt   — step executables
  target.tensors, draft.tensors             — trained weights (tensorfile)
  corpus.bin                                — synthetic corpus (bench prompts)
  manifest.json                             — shapes/dims/tiles for Rust
  train_log.json                            — loss curves (EXPERIMENTS.md)

`make artifacts` skips this when outputs are newer than inputs.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tensorfile
from .configs import DRAFT, TARGET, TRAIN, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Tile variants compiled per model: the runtime picks the smallest tile
# that fits the nodes of one eval call, so single-token decode does not
# pay for a 32-wide tile (EXPERIMENTS.md §Perf iteration 2).
S_TILES = [1, 4, 8, 16, 32]


def lower_step(cfg: ModelConfig, s_tile: int, *, use_pallas: bool = True) -> str:
    """Lower step() for `cfg` at tile width `s_tile` (weights as inputs)."""
    B, S, Mlen = cfg.batch, s_tile, cfg.cache_len
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.d_head

    def fn(params: M.Params, tokens, positions, dest, attn_mask, kc, vc):
        logits, kc2, vc2 = M.step(cfg, params, tokens, positions, dest,
                                  attn_mask, kc, vc, use_pallas=use_pallas)
        return logits, kc2, vc2

    f32, i32 = jnp.float32, jnp.int32
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    pspec = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), p0)
    cache = jax.ShapeDtypeStruct((L, B, H, Mlen, Dh), M.CACHE_DTYPE)
    args = (
        pspec,
        jax.ShapeDtypeStruct((B, S), i32),
        jax.ShapeDtypeStruct((B, S), i32),
        jax.ShapeDtypeStruct((B, S), i32),
        jax.ShapeDtypeStruct((B, S, Mlen), f32),
        cache, cache,
    )
    # donate the KV caches: they are pure state threaded through the call.
    lowered = jax.jit(fn, donate_argnums=(5, 6)).lower(*args)
    return to_hlo_text(lowered)


def params_to_tensors(params: M.Params) -> dict:
    return {name: np.asarray(getattr(params, name))
            for name in M.PARAM_FIELDS}


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="random-init weights (CI smoke mode)")
    ap.add_argument("--retrain", action="store_true",
                    help="force re-training even when weights exist")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    t_path = os.path.join(out, "target.tensors")
    d_path = os.path.join(out, "draft.tensors")
    c_path = os.path.join(out, "corpus.bin")
    if args.skip_train:
        raw = b"\x00" * 4096
        tparams = M.init_params(TARGET, jax.random.PRNGKey(1))
        dparams = M.init_params(DRAFT, jax.random.PRNGKey(2))
        logs = {"target": [], "draft": [], "skip_train": True}
    elif (not args.retrain and os.path.exists(t_path) and os.path.exists(d_path)
          and os.path.exists(c_path)):
        # reuse the trained checkpoint; re-lower only (tile changes etc.)
        print("reusing existing weights (pass --retrain to re-train)")
        with open(c_path, "rb") as f:
            raw = f.read()
        tparams = M.Params(**{k: jnp.asarray(v) for k, v in
                              tensorfile.load(t_path).items()})
        dparams = M.Params(**{k: jnp.asarray(v) for k, v in
                              tensorfile.load(d_path).items()})
        logs = {"target": [], "draft": [], "reused": True}
    else:
        from . import train as train_mod
        raw, tparams, dparams, logs = train_mod.run(TRAIN)

    with open(os.path.join(out, "corpus.bin"), "wb") as f:
        f.write(raw)
    tensorfile.save(os.path.join(out, "target.tensors"),
                    params_to_tensors(tparams))
    tensorfile.save(os.path.join(out, "draft.tensors"),
                    params_to_tensors(dparams))

    manifest = {"models": {}, "param_fields": M.PARAM_FIELDS}
    for cfg, params in ((TARGET, tparams), (DRAFT, dparams)):
        tiles = {}
        for s_tile in S_TILES:
            hlo = lower_step(cfg, s_tile, use_pallas=True)
            name = f"{cfg.name}_step_s{s_tile}.hlo.txt"
            hlo_path = os.path.join(out, name)
            with open(hlo_path, "w") as f:
                f.write(hlo)
            print(f"wrote {hlo_path}: {len(hlo)} chars")
            tiles[str(s_tile)] = {"hlo": name, "hlo_sha256": _sha256(hlo_path)}
        # keep the legacy single-tile alias pointing at the widest tile
        manifest["models"][cfg.name] = {
            **cfg.to_dict(),
            "hlo": tiles[str(max(S_TILES))]["hlo"],
            "tiles": tiles,
            "tensors": f"{cfg.name}.tensors",
            "tensors_sha256": _sha256(os.path.join(out, f"{cfg.name}.tensors")),
            # input order for the rust runtime: params fields, then operands
            "input_order": M.PARAM_FIELDS + [
                "tokens", "positions", "dest", "attn_mask", "kcache", "vcache"],
            "outputs": ["logits", "kcache", "vcache"],
        }
    with open(os.path.join(out, "train_log.json"), "w") as f:
        json.dump(logs, f)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
