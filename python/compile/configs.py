"""Model + artifact configurations shared by the compile path.

Two checkpoints are built: a *target* LM (~2.9M params) trained on the
synthetic corpus and a *draft* LM (~0.12M params) distilled from the
target. Both share the same step-executable contract (see DESIGN.md §1);
only the dimensions differ. The size ratio (~24x) drives MBSU the same
way the paper's 7B/115M pairing does.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    d_ff: int = 512
    # step-executable tile sizes (static shapes)
    s_tile: int = 32        # max tokens per step call (tree width / prefill chunk)
    cache_len: int = 256    # M: KV-cache slots; slot M-1 is the padding scratch slot
    batch: int = 1
    rope_theta: float = 10000.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        L, D, F, V = self.n_layers, self.d_model, self.d_ff, self.vocab
        attn = 4 * D * D
        ffn = 3 * D * F
        norms = 2 * D
        return V * D + L * (attn + ffn + norms) + D + D * V

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        d["params"] = self.param_count()
        return d


TARGET = ModelConfig(name="target", n_layers=4, d_model=256, n_heads=4, d_ff=512)
DRAFT = ModelConfig(name="draft", n_layers=2, d_model=64, n_heads=2, d_ff=128)


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    seq_len: int = 96
    batch: int = 8
    target_steps: int = 400
    draft_steps: int = 300
    lr: float = 3e-3
    warmup: int = 20
    corpus_chars: int = 1_000_000
    distill_kl_weight: float = 1.0  # draft trains on pure KL to target


TRAIN = TrainConfig()
